//! # Static ISA verifier: dataflow lint over recorded programs
//!
//! Every workload in this crate lowers to a straight-line
//! [`crate::sim::Program`] before it executes. That makes the full
//! dataflow of a kernel statically decidable — there are no branches, no
//! memory, just 32 vector registers, 8 mask registers and a journal of
//! harness-side loads — so the hazards the simulator (or the graph
//! lifter) can only trip over *dynamically* can be reported *before*
//! execution, with instruction indices attached. This module is that
//! check: an abstract interpreter ([`Verifier`]) over the typestate
//! lattice of [`typestate`], wired into the [`crate::engine::Engine`]
//! as a verify-before-run gate.
//!
//! ## The typestate lattice
//!
//! Each vector register `v0`–`v31` is `Undef`, `Ext` (externally loaded
//! by the harness, per the position-aware [`Externals`] journal) or
//! `Def` (instruction-written at a known index), and carries the lane
//! type of its definition when one is known — takum writes pin
//! `Takum(w)`, IEEE/OFP8 writes pin `Mini`/`MiniSat` specs, while
//! integer-domain ops (bitwise, shifts, integer lanes, mask→vector
//! moves) install *untyped* raw-bit definitions compatible with any
//! later read. Mask registers `k0`–`k7` track set/unset, with `k0`
//! architecturally "no mask". Readback compatibility is exact type
//! equality plus the saturating-encode split
//! ([`typestate::compatible`]): `VCVTPH2HF8S` writes `MiniSat(E4M3)`
//! lanes that `VCVTHF82PH` legitimately reads back as `Mini(E4M3)`.
//!
//! ## The diagnostic catalogue
//!
//! | kind ([`DiagKind`])   | severity | meaning                                            |
//! |-----------------------|----------|----------------------------------------------------|
//! | `type-mismatch`       | error    | lanes written as one type, read as another with no convert — the bit-reinterpretation hazard `Graph::lift` rejects dynamically, hoisted static |
//! | `use-before-def`      | error    | register read with no prior write or journalled external load |
//! | `unset-mask`          | error    | `{k}`-masked op whose mask register is never set (silently drops every lane) |
//! | `irregular-mnemonic`  | error    | mnemonic unresolvable by [`crate::sim::LanePlan::resolve`], or operands that don't fit the resolved plan |
//! | `dead-write`          | warning  | write overwritten before any read — wasteful, never value-corrupting |
//!
//! Alongside the diagnostics, every verification computes a
//! [`StaticMix`]: the per-mnemonic histogram, total, convert and
//! widening-dot counts the program *will* execute — a static model of
//! the paper's instruction-mix metrics, pinned against the dynamic
//! counts by the differential fuzz suite.
//!
//! ## Policy: Off / Warn / Deny
//!
//! The engine carries a [`Verify`] policy
//! ([`crate::engine::EngineConfig::verify`], env `TAKUM_VERIFY`, CLI
//! `--verify`): `Off` skips the pass, `Warn` prints diagnostics to
//! stderr and runs anyway, `Deny` refuses to execute any program with
//! **error**-severity diagnostics (warnings — dead writes — never
//! block; randomly generated corpora legitimately contain them). The
//! gate sits in the engine's job paths: kernel-suite cells verify the
//! traced lowering (with the builder's external-load journal), and raw
//! programs submitted as [`crate::engine::Job::Program`] verify under
//! implicit-inputs semantics (undefined registers read as architectural
//! zeros, exactly the lifter's convention). The `lint` CLI subcommand
//! runs the same pass over the whole kernel suite × format matrix and
//! reports per-cell diagnostics, static mixes and the
//! [`crate::isa::database`] cross-check.

pub mod dataflow;
pub mod diag;
pub mod typestate;

pub use dataflow::{verify_program, Externals, Verifier};
pub use diag::{DiagKind, Diagnostic, Report, Severity, StaticMix};
pub use typestate::{compatible, KState, VState};

use anyhow::{bail, Result};

/// The engine's verify-before-run policy (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Verify {
    /// Skip static verification entirely.
    #[default]
    Off,
    /// Verify, print every diagnostic to stderr, run anyway.
    Warn,
    /// Verify and refuse to execute programs with error-severity
    /// diagnostics (warnings still print and pass).
    Deny,
}

impl Verify {
    /// Every policy, in escalation order.
    pub const ALL: [Verify; 3] = [Verify::Off, Verify::Warn, Verify::Deny];

    pub fn name(&self) -> &'static str {
        match self {
            Verify::Off => "off",
            Verify::Warn => "warn",
            Verify::Deny => "deny",
        }
    }

    pub fn parse(s: &str) -> Result<Verify> {
        for v in Verify::ALL {
            if v.name() == s {
                return Ok(v);
            }
        }
        let names: Vec<&str> = Verify::ALL.iter().map(|v| v.name()).collect();
        bail!("unknown verify policy {s:?} (expected one of: {})", names.join("|"))
    }

    /// Resolve the value of the `TAKUM_VERIFY` environment variable
    /// (`None` = unset): malformed values warn and fall back to `Off`
    /// rather than failing engine construction. The env read itself
    /// lives in [`crate::engine::EngineConfig::from_env`]; this is the
    /// pure, unit-testable half.
    pub fn parse_env(var: Option<&str>) -> Verify {
        match var {
            Some(v) => Verify::parse(v).unwrap_or_else(|e| {
                eprintln!("warning: TAKUM_VERIFY: {e}; verification off");
                Verify::Off
            }),
            None => Verify::Off,
        }
    }
}

/// Cross-check a static mix against the ISA database: every mnemonic the
/// program uses that appears in neither the AVX10.2 baseline tables nor
/// the proposed-extension tables. Informational — the kernel builders
/// emit a handful of glue spellings (legacy width-suffixed bitwise ops)
/// that the paper's tables don't enumerate — but a sudden growth here
/// means a lowering drifted away from the ISA under study.
pub fn isa_cross_check(mix: &StaticMix) -> Vec<&'static str> {
    mix.histogram
        .keys()
        .copied()
        .filter(|m| !crate::isa::database::known_mnemonic(m))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_round_trips() {
        for v in Verify::ALL {
            assert_eq!(Verify::parse(v.name()).unwrap(), v);
        }
        assert!(Verify::parse("paranoid").is_err());
        assert_eq!(Verify::parse_env(None), Verify::Off);
        assert_eq!(Verify::parse_env(Some("deny")), Verify::Deny);
        assert_eq!(Verify::parse_env(Some("bogus")), Verify::Off);
        assert_eq!(Verify::default(), Verify::Off);
    }

    /// The whole kernel suite — every kernel × every format, both ISAs —
    /// verifies with ZERO diagnostics (not even dead-write warnings):
    /// the lowerings in `kernels::workloads` are hazard-free by
    /// construction, and this pins that they stay so.
    #[test]
    fn kernel_suite_corpus_is_clean() {
        use crate::engine::EngineConfig;
        use crate::kernels::{Kernel, KernelSpec, Pipeline};

        let eng = EngineConfig::new().verify(Verify::Warn).build().unwrap();
        for kernel in Kernel::ALL {
            for format in Pipeline::ALL_FORMATS {
                let spec = KernelSpec { kernel, format, n: 64, seed: 7 };
                let run = spec.lower(&eng).unwrap();
                let report = run.report.expect("verify=warn engines produce reports");
                assert!(
                    report.is_clean(),
                    "{}/{format} is not hazard-free:\n{}",
                    kernel.name(),
                    report.render_diagnostics()
                );
                assert!(report.mix.total > 0);
                // The static mix agrees with what actually executed.
                assert_eq!(report.mix.total as u64, run.machine.executed);
            }
        }
    }

    /// Every mnemonic the suite's lowerings emit is accounted for in the
    /// ISA database tables (baseline or proposed), modulo a pinned
    /// allowlist of spellings the paper's patterns don't capture: the
    /// takum↔takum width narrowings (the proposed convert matrix is
    /// int↔takum only), the real-hardware OFP8 store converts
    /// (`VCVTPH2HF8S`/`VCVTPH2BF8S` — the table mandates a `BIAS|NE`
    /// prefix) and `VCVTBF82PH`, and the `NEPBF16` spellings of
    /// `VMAX`/`VSCALEF` that the F03 row writes as `PBF16`. Anything
    /// outside the allowlist means a lowering drifted off the ISA under
    /// study.
    #[test]
    fn kernel_suite_mnemonics_are_known_to_the_isa_database() {
        use crate::engine::EngineConfig;
        use crate::kernels::{Kernel, KernelSpec, Pipeline};

        const ALLOWED_GLUE: [&str; 7] = [
            "VCVTPT162PT8",
            "VCVTPT322PT16",
            "VCVTPH2HF8S",
            "VCVTPH2BF8S",
            "VCVTBF82PH",
            "VMAXNEPBF16",
            "VSCALEFNEPBF16",
        ];
        let eng = EngineConfig::new().verify(Verify::Warn).build().unwrap();
        for kernel in Kernel::ALL {
            for format in Pipeline::ALL_FORMATS {
                let spec = KernelSpec { kernel, format, n: 64, seed: 3 };
                let run = spec.lower(&eng).unwrap();
                let report = run.report.expect("verify=warn engines produce reports");
                let unknown: Vec<&str> = isa_cross_check(&report.mix)
                    .into_iter()
                    .filter(|m| !ALLOWED_GLUE.contains(m))
                    .collect();
                assert!(
                    unknown.is_empty(),
                    "{}/{format} uses mnemonics outside the ISA tables: {unknown:?}",
                    kernel.name()
                );
            }
        }
    }
}
