//! The typed diagnostic catalogue and the per-program report.

use std::collections::BTreeMap;
use std::fmt;

/// How a diagnostic interacts with the `Verify::Deny` policy: errors
/// block execution, warnings are advisory (a dead write is wasteful but
/// cannot corrupt results, so randomly generated corpora may carry them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

/// Every defect class the static verifier can report.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DiagKind {
    /// A register's lanes are read under a lane type incompatible with
    /// the type they were written as, with no convert in between — the
    /// bit-reinterpretation hazard `Graph::lift` rejects dynamically,
    /// hoisted to a static check.
    TypeMismatch,
    /// A vector or mask register is read before any instruction write or
    /// journalled external load defines it.
    UseBeforeDef,
    /// An instruction write is overwritten by a later full (unmasked or
    /// zeroing) write with no intervening read — wasted work. Warning
    /// severity: never blocks `Verify::Deny`.
    DeadWrite,
    /// A masked or zeroing write names a mask register that is never set
    /// (neither written by a mask-producing instruction nor journalled
    /// as external state). `k0` is architecturally "no mask" and exempt.
    UnsetMask,
    /// The mnemonic does not decompose into op + lane suffix under
    /// [`crate::sim::LanePlan::resolve`], or its operands do not fit the
    /// resolved plan's shape.
    IrregularMnemonic,
}

impl DiagKind {
    pub const ALL: [DiagKind; 5] = [
        DiagKind::TypeMismatch,
        DiagKind::UseBeforeDef,
        DiagKind::DeadWrite,
        DiagKind::UnsetMask,
        DiagKind::IrregularMnemonic,
    ];

    pub fn name(self) -> &'static str {
        match self {
            DiagKind::TypeMismatch => "type-mismatch",
            DiagKind::UseBeforeDef => "use-before-def",
            DiagKind::DeadWrite => "dead-write",
            DiagKind::UnsetMask => "unset-mask",
            DiagKind::IrregularMnemonic => "irregular-mnemonic",
        }
    }

    pub fn severity(self) -> Severity {
        match self {
            DiagKind::DeadWrite => Severity::Warning,
            _ => Severity::Error,
        }
    }
}

/// One finding, anchored to the instruction index it fires at.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub kind: DiagKind,
    /// Index into `Program::instrs` of the instruction the diagnostic
    /// anchors to.
    pub at: usize,
    /// Human-readable detail (registers, both lane types, the second
    /// instruction index where relevant).
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{}: {}: {}", self.at, self.kind.name(), self.message)
    }
}

/// The static instruction-mix model: what the program *will* execute,
/// computed without running it. On any program the simulator accepts,
/// `histogram` equals `Program::histogram()` and matches the machine's
/// executed counts one-for-one (pinned by the differential fuzz suite).
#[derive(Debug, Clone, Default)]
pub struct StaticMix {
    /// Total instructions.
    pub total: usize,
    /// Instructions whose plan is a format conversion — the static
    /// convert-tax model (the paper's OFP8 promote/demote accounting).
    pub converts: usize,
    /// Widening dot products.
    pub dots: usize,
    /// Per-mnemonic counts (interned keys, borrowed not cloned).
    pub histogram: BTreeMap<&'static str, usize>,
}

/// Outcome of verifying one program: the diagnostics plus the static mix.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    pub mix: StaticMix,
}

impl Report {
    /// No diagnostics at all — not even warnings.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn count(&self, kind: DiagKind) -> usize {
        self.diagnostics.iter().filter(|d| d.kind == kind).count()
    }

    pub fn error_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.kind.severity() == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.len() - self.error_count()
    }

    /// Whether `Verify::Deny` lets the program run: no error-severity
    /// diagnostics (warnings pass).
    pub fn passes_deny(&self) -> bool {
        self.error_count() == 0
    }

    /// Multi-line listing of every diagnostic (empty string when clean).
    pub fn render_diagnostics(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("  {d}\n"));
        }
        out
    }

    /// One-line metrics summary of the static mix.
    pub fn render_mix(&self) -> String {
        format!(
            "{} instructions, {} distinct mnemonics, {} converts, {} dots",
            self.mix.total,
            self.mix.histogram.len(),
            self.mix.converts,
            self.mix.dots
        )
    }
}
