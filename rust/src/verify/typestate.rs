//! The per-register typestate lattice the dataflow interpreter walks.
//!
//! Each vector register is in one of three states — never defined,
//! externally defined (harness data I/O outside the instruction stream),
//! or instruction-defined at a known index — and carries the lane type
//! of its last definition when one is known. Integer-domain writes
//! (bitwise, shifts, integer lane ops, mask→vector moves) install an
//! *untyped* definition: they manipulate raw bits and are compatible
//! with any later read. Mask registers only need set/unset tracking.

use crate::sim::LaneType;

/// Typestate of one vector register (`v0`–`v31`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum VState {
    /// Never written by an instruction and never externally loaded.
    Undef,
    /// Externally loaded by the harness (a journalled
    /// [`super::Externals`] event); `None` means type-polymorphic
    /// external state (e.g. the builder's all-zero constant register,
    /// whose bit pattern decodes to 0.0 in every format).
    Ext(Option<LaneType>),
    /// Defined by the instruction at index `at`. `ty: None` is an
    /// untyped (raw-bit) definition; `read` flips once any later
    /// instruction consumes the value (dead-write tracking).
    Def { ty: Option<LaneType>, at: usize, read: bool },
}

impl VState {
    /// The lane type this state pins, if any.
    pub fn ty(&self) -> Option<LaneType> {
        match self {
            VState::Undef => None,
            VState::Ext(t) => *t,
            VState::Def { ty, .. } => *ty,
        }
    }
}

/// Typestate of one mask register (`k0`–`k7`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KState {
    Undef,
    /// Set by a mask-producing instruction (mask op, compare, `VCLASS`,
    /// vector→mask move) or journalled as external state.
    Def,
}

/// Readback compatibility: can lanes written as `a` be read as `b`
/// without a bit reinterpretation? Exact type equality, plus the
/// saturating/non-saturating encode split of one IEEE spec —
/// `VCVTPH2HF8S` *writes* saturating E4M3 lanes which `VCVTHF82PH`
/// *reads* back as plain E4M3; the bits are the same format either way.
pub fn compatible(a: LaneType, b: LaneType) -> bool {
    if a == b {
        return true;
    }
    match (a, b) {
        (LaneType::Mini(x), LaneType::MiniSat(y)) | (LaneType::MiniSat(x), LaneType::Mini(y)) => {
            x == y
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::num::{E4M3, E5M2};

    #[test]
    fn compatibility_is_spec_equality_modulo_saturation() {
        assert!(compatible(LaneType::Takum(8), LaneType::Takum(8)));
        assert!(!compatible(LaneType::Takum(8), LaneType::Takum(16)));
        assert!(!compatible(LaneType::Takum(8), LaneType::Mini(E4M3)));
        // The VCVT…S store / plain load round trip.
        assert!(compatible(LaneType::MiniSat(E4M3), LaneType::Mini(E4M3)));
        assert!(compatible(LaneType::Mini(E5M2), LaneType::MiniSat(E5M2)));
        assert!(!compatible(LaneType::MiniSat(E4M3), LaneType::Mini(E5M2)));
    }

    #[test]
    fn state_type_projection() {
        assert_eq!(VState::Undef.ty(), None);
        assert_eq!(VState::Ext(Some(LaneType::Takum(16))).ty(), Some(LaneType::Takum(16)));
        assert_eq!(VState::Ext(None).ty(), None);
        let d = VState::Def { ty: Some(LaneType::Takum(8)), at: 3, read: false };
        assert_eq!(d.ty(), Some(LaneType::Takum(8)));
    }
}
