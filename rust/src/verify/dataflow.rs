//! The abstract interpreter: one linear pass over a [`Program`],
//! resolving each mnemonic through [`LanePlan::resolve`] and walking the
//! [`VState`]/[`KState`] lattice with the exact operand conventions of
//! the executor (`sim::exec`) — FMAs and dot products read their
//! destination, merging masked writes read the old destination at the
//! write type, zeroing and unmasked writes kill it, compares and `VCLASS`
//! define mask registers, integer-domain ops read and write raw bits.

use super::diag::{DiagKind, Diagnostic, Report};
use super::typestate::{compatible, KState, VState};
use crate::sim::lanes::{FpOp, LanePlan};
use crate::sim::{Instruction, LaneType, Operand, Program};
use std::collections::HashMap;

const NUM_VREGS: usize = 32;
const NUM_KREGS: usize = 8;

/// One journalled piece of machine state installed from outside the
/// instruction stream.
#[derive(Debug, Clone, Copy)]
enum Event {
    /// `Machine::load_f64(reg, ty, …)`; `ty: None` declares the register
    /// type-polymorphic (readable as anything — the all-zero constant).
    LoadV { reg: u8, ty: Option<LaneType> },
    /// `Machine::set_mask(k, …)`.
    SetMask { k: u8 },
    /// A harness-side data read (`Machine::read_f64`): consumes the
    /// register's current value through the data-I/O path, keeping the
    /// defining write live.
    ReadV { reg: u8 },
}

/// The external-state journal: harness-side data I/O interleaved with
/// the instruction stream. Each event carries the instruction index it
/// precedes (`at == 0` is initial state; `at == program.len()` follows
/// the last instruction), because kernels reload scratch registers
/// *between* instructions — a reduction tree loads shuffled halves
/// mid-program, so position matters for both typestate and dead-write
/// analysis.
#[derive(Debug, Clone, Default)]
pub struct Externals {
    events: Vec<(usize, Event)>,
}

impl Externals {
    pub fn new() -> Externals {
        Externals::default()
    }

    /// Journal a typed external vector load applied before instruction
    /// index `at`.
    pub fn load(&mut self, at: usize, reg: u8, ty: LaneType) {
        self.events.push((at, Event::LoadV { reg, ty: Some(ty) }));
    }

    /// Journal a type-polymorphic external vector definition (readable
    /// under any lane type without reinterpretation hazard).
    pub fn load_untyped(&mut self, at: usize, reg: u8) {
        self.events.push((at, Event::LoadV { reg, ty: None }));
    }

    /// Journal an external mask-register write applied before
    /// instruction index `at`.
    pub fn set_mask(&mut self, at: usize, k: u8) {
        self.events.push((at, Event::SetMask { k }));
    }

    /// Journal a harness-side data read of a vector register before
    /// instruction index `at` — the consumption that keeps a kernel's
    /// per-tile result live even though no *instruction* ever reads it
    /// (store → `read_*` → next tile overwrites).
    pub fn read(&mut self, at: usize, reg: u8) {
        self.events.push((at, Event::ReadV { reg }));
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// The verifier: configuration (external journal, input policy) + the
/// [`Verifier::verify`] entry point. See the module docs of
/// [`crate::verify`] for the diagnostic catalogue and lattice.
#[derive(Debug, Clone, Default)]
pub struct Verifier {
    externals: Externals,
    implicit_inputs: bool,
}

impl Verifier {
    /// Strict verifier: no external state, every read must be preceded
    /// by an instruction write.
    pub fn new() -> Verifier {
        Verifier::default()
    }

    /// Verifier with a journal of harness-side loads/mask writes.
    pub fn with_externals(externals: Externals) -> Verifier {
        Verifier { externals, implicit_inputs: false }
    }

    /// Treat reads of never-defined registers as implicit zero inputs
    /// instead of use-before-def errors — the lifter's semantics, used
    /// for raw programs run against a freshly zeroed machine (the fuzz
    /// corpus, `simulate` on an assembly file). Type-mismatch, unset
    /// mask and irregular-mnemonic checks stay fully active.
    pub fn implicit_inputs(mut self, yes: bool) -> Verifier {
        self.implicit_inputs = yes;
        self
    }

    /// Run the dataflow pass and produce the report.
    pub fn verify(&self, prog: &Program) -> Report {
        let mut events = self.externals.events.clone();
        events.sort_by_key(|(at, _)| *at);
        let mut st = State {
            v: [VState::Undef; NUM_VREGS],
            k: [KState::Undef; NUM_KREGS],
            implicit: self.implicit_inputs,
            diags: Vec::new(),
        };
        // k0 is architecturally "no mask" (all lanes active): always set.
        st.k[0] = KState::Def;

        let mut report = Report::default();
        let mut plans: HashMap<&'static str, Option<LanePlan>> = HashMap::new();
        let mut cursor = 0usize;
        for (at, ins) in prog.instrs.iter().enumerate() {
            while cursor < events.len() && events[cursor].0 <= at {
                st.apply_event(events[cursor].1);
                cursor += 1;
            }
            report.mix.total += 1;
            *report.mix.histogram.entry(ins.mnemonic).or_default() += 1;
            let plan = *plans
                .entry(ins.mnemonic)
                .or_insert_with(|| LanePlan::resolve(ins.mnemonic).ok());
            match plan {
                None => {
                    // Re-resolve for the error detail; resolution is pure.
                    let why = LanePlan::resolve(ins.mnemonic)
                        .err()
                        .map(|e| e.to_string())
                        .unwrap_or_else(|| "unresolvable".into());
                    st.diag(
                        DiagKind::IrregularMnemonic,
                        at,
                        format!("{}: {}", ins.mnemonic, why),
                    );
                }
                Some(plan) => {
                    match plan {
                        LanePlan::Convert { .. } | LanePlan::ConvertNe2PsBf16 => {
                            report.mix.converts += 1
                        }
                        LanePlan::Dot { .. } => report.mix.dots += 1,
                        _ => {}
                    }
                    st.step(at, ins, plan);
                }
            }
        }
        report.diagnostics = st.diags;
        report
    }
}

/// Convenience: strict verification of a self-contained program.
pub fn verify_program(prog: &Program) -> Report {
    Verifier::new().verify(prog)
}

// ---------------------------------------------------------------------------
// The interpreter state
// ---------------------------------------------------------------------------

struct State {
    v: [VState; NUM_VREGS],
    k: [KState; NUM_KREGS],
    implicit: bool,
    diags: Vec<Diagnostic>,
}

fn vreg(op: &Operand) -> Option<u8> {
    match op {
        Operand::Vreg(r) => Some(*r),
        _ => None,
    }
}

fn kreg(op: &Operand) -> Option<u8> {
    match op {
        Operand::Kreg(r) => Some(*r),
        _ => None,
    }
}

impl State {
    fn diag(&mut self, kind: DiagKind, at: usize, message: String) {
        self.diags.push(Diagnostic { kind, at, message });
    }

    fn apply_event(&mut self, ev: Event) {
        match ev {
            // An external load replaces whatever was there. It does NOT
            // flag an unread previous write as dead: the harness may
            // have read the register through the data-I/O path before
            // reloading it (store-narrow → read-back → next tile).
            Event::LoadV { reg, ty } => self.v[reg as usize] = VState::Ext(ty),
            Event::SetMask { k } => self.k[k as usize] = KState::Def,
            // A data read consumes the value: the defining write is live.
            Event::ReadV { reg } => {
                if let VState::Def { read, .. } = &mut self.v[reg as usize] {
                    *read = true;
                }
            }
        }
    }

    /// Read vector register `r` under lane type `ty` (`None` = raw-bit
    /// read, any type acceptable) at instruction `at`.
    fn read_v(&mut self, r: u8, ty: Option<LaneType>, at: usize) {
        let i = r as usize;
        match self.v[i] {
            VState::Undef => {
                if !self.implicit {
                    self.diag(
                        DiagKind::UseBeforeDef,
                        at,
                        format!("v{r} read before any write or external load"),
                    );
                }
            }
            VState::Ext(held) => {
                if let (Some(h), Some(want)) = (held, ty) {
                    if !compatible(h, want) {
                        self.diag(
                            DiagKind::TypeMismatch,
                            at,
                            format!(
                                "v{r} holds {h:?} (external load) but is read as {want:?} \
                                 without a convert (bit reinterpretation)"
                            ),
                        );
                    }
                }
            }
            VState::Def { ty: held, at: def_at, .. } => {
                if let (Some(h), Some(want)) = (held, ty) {
                    if !compatible(h, want) {
                        self.diag(
                            DiagKind::TypeMismatch,
                            at,
                            format!(
                                "v{r} written as {h:?} at #{def_at} but read as {want:?} \
                                 without a convert (bit reinterpretation)"
                            ),
                        );
                    }
                }
                if let VState::Def { read, .. } = &mut self.v[i] {
                    *read = true;
                }
            }
        }
    }

    /// Read mask register `r` as a data source (mask ops, mask→vector).
    fn read_k(&mut self, r: u8, at: usize) {
        if self.k[r as usize] == KState::Undef && !self.implicit {
            self.diag(
                DiagKind::UseBeforeDef,
                at,
                format!("k{r} read before any mask write"),
            );
        }
    }

    /// A `{k}` write/read mask on instruction `at`: `k0` means no mask;
    /// any other unset register is an error regardless of input policy
    /// (an all-zero mask silently drops every lane).
    fn use_mask(&mut self, ins: &Instruction, at: usize) {
        if let Some(k) = ins.mask {
            if k != 0 && self.k[k as usize] == KState::Undef {
                self.diag(
                    DiagKind::UnsetMask,
                    at,
                    format!(
                        "{} masked with k{k}, which is never set",
                        ins.mnemonic
                    ),
                );
            }
        }
    }

    /// Define vector register `r` at `at`. `kills` = the write fully
    /// determines the register (unmasked packed, or zeroing-masked), so
    /// an unread previous instruction write becomes a dead write.
    fn write_v(&mut self, r: u8, ty: Option<LaneType>, at: usize, kills: bool) {
        let i = r as usize;
        if kills {
            if let VState::Def { at: prev, read: false, .. } = self.v[i] {
                self.diag(
                    DiagKind::DeadWrite,
                    at,
                    format!("v{r} written at #{prev} is overwritten at #{at} before any read"),
                );
            }
        }
        self.v[i] = VState::Def { ty, at, read: false };
    }

    fn write_k(&mut self, r: u8) {
        self.k[r as usize] = KState::Def;
    }

    /// Malformed operand shape for the resolved plan.
    fn irregular(&mut self, at: usize, ins: &Instruction, what: &str) {
        self.diag(
            DiagKind::IrregularMnemonic,
            at,
            format!("{}: {what}", ins.mnemonic),
        );
    }

    /// Read every vector-register source under `ty` and every
    /// mask-register source as data (immediates pass through untouched).
    fn read_srcs(&mut self, ins: &Instruction, ty: Option<LaneType>, at: usize) {
        for s in &ins.srcs {
            match s {
                Operand::Vreg(r) => self.read_v(*r, ty, at),
                Operand::Kreg(r) => self.read_k(*r, at),
                Operand::Imm(_) => {}
            }
        }
    }

    /// The common vector-destination epilogue: mask check, optional
    /// merge-read of the old destination at the write type, then the
    /// define (kill analysis per mask/zeroing/partial semantics).
    fn write_vdst(
        &mut self,
        ins: &Instruction,
        at: usize,
        ty: Option<LaneType>,
        partial: bool,
        reads_dst: bool,
    ) {
        let Some(dst) = vreg(&ins.dst) else {
            return self.irregular(at, ins, "destination must be a vector register");
        };
        self.use_mask(ins, at);
        let masked = matches!(ins.mask, Some(k) if k != 0);
        let merging = (masked && !ins.zeroing) || partial;
        if merging || reads_dst {
            // Merging keeps inactive lanes: the old value is consumed at
            // the write type (so is an FMA/dot accumulator input).
            self.read_v(dst, ty, at);
        }
        let kills = !merging && !reads_dst;
        self.write_v(dst, ty, at, kills);
    }

    fn write_kdst(&mut self, ins: &Instruction, at: usize) {
        match kreg(&ins.dst) {
            Some(dst) => {
                self.use_mask(ins, at);
                self.write_k(dst);
            }
            None => self.irregular(at, ins, "destination must be a mask register"),
        }
    }

    /// One instruction through the lattice, mirroring the executor's
    /// per-plan operand conventions.
    fn step(&mut self, at: usize, ins: &Instruction, plan: LanePlan) {
        match plan {
            LanePlan::Fp { op, ty, packed } => {
                self.read_srcs(ins, Some(ty), at);
                if matches!(op, FpOp::Class) {
                    // VCLASS writes a mask register.
                    self.write_kdst(ins, at);
                } else {
                    let fma = matches!(op, FpOp::Fma(..));
                    self.write_vdst(ins, at, Some(ty), !packed, fma);
                }
            }
            LanePlan::Convert { src, dst } => {
                self.read_srcs(ins, Some(src), at);
                self.write_vdst(ins, at, Some(dst), false, false);
            }
            LanePlan::ConvertNe2PsBf16 => {
                self.read_srcs(ins, Some(LaneType::Mini(crate::num::F32)), at);
                self.write_vdst(ins, at, Some(LaneType::Mini(crate::num::BF16)), false, false);
            }
            LanePlan::Dot { src, dst } => {
                self.read_srcs(ins, Some(src), at);
                // The accumulator is always read, even unmasked.
                self.write_vdst(ins, at, Some(dst), false, true);
            }
            LanePlan::Compare { ty, .. } => {
                self.read_srcs(ins, Some(ty), at);
                self.write_kdst(ins, at);
            }
            LanePlan::Bitwise(_) | LanePlan::Shift(..) | LanePlan::Int(_) => {
                // Integer domain: raw-bit reads, untyped definition.
                self.read_srcs(ins, None, at);
                self.write_vdst(ins, at, None, false, false);
            }
            LanePlan::Broadcast(w) => {
                let src_ty = match ins.srcs.first().and_then(vreg) {
                    Some(r) => {
                        self.read_v(r, None, at);
                        self.v[r as usize].ty()
                    }
                    None => {
                        self.irregular(at, ins, "broadcast needs a vector source");
                        None
                    }
                };
                // A lane broadcast at width `w` propagates the source
                // type when the widths agree; a width clash is the same
                // reinterpretation hazard as a mistyped read. Block
                // broadcasts (128/256) shuffle raw sub-registers.
                let ty = match src_ty {
                    Some(t) if w <= 64 && t.width() == w => Some(t),
                    Some(t) if w <= 64 => {
                        self.diag(
                            DiagKind::TypeMismatch,
                            at,
                            format!(
                                "{} broadcasts {w}-bit lanes from a register holding \
                                 {t:?} ({}-bit lanes)",
                                ins.mnemonic,
                                t.width()
                            ),
                        );
                        None
                    }
                    _ => None,
                };
                self.write_vdst(ins, at, ty, false, false);
            }
            LanePlan::VecToMask(_) => {
                self.read_srcs(ins, None, at);
                self.write_kdst(ins, at);
            }
            LanePlan::MaskToVec(_) => {
                self.read_srcs(ins, None, at);
                self.write_vdst(ins, at, None, false, false);
            }
            LanePlan::Mask(_) => {
                // Mask ops read mask registers (KUNPCK/binaries two, NOT/
                // MOV/shifts one) and define the mask destination.
                for s in &ins.srcs {
                    match s {
                        Operand::Kreg(r) => self.read_k(*r, at),
                        Operand::Imm(_) => {}
                        Operand::Vreg(_) => {
                            self.irregular(at, ins, "mask op sources must be mask registers");
                        }
                    }
                }
                match kreg(&ins.dst) {
                    Some(dst) => self.write_k(dst),
                    None => self.irregular(at, ins, "destination must be a mask register"),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::num::E4M3;
    use crate::sim::{Instruction, LaneType, Operand, Program};

    const T16: LaneType = LaneType::Takum(16);
    const T8: LaneType = LaneType::Takum(8);

    fn v(r: u8) -> Operand {
        Operand::Vreg(r)
    }

    fn fp(m: &str, dst: u8, a: u8, b: u8) -> Instruction {
        Instruction::new(m, v(dst), vec![v(a), v(b)])
    }

    /// v0/v1 preloaded as takum16, then v2 = v0 + v1 read back wrongly as
    /// takum8 — the bit-reinterpretation hazard, anchored to the
    /// offending read's index.
    #[test]
    fn detects_type_mismatch_read() {
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1)); // #0: v2 := t16
        p.push(fp("VMULPT8", 3, 2, 2)); // #1: reads v2 as t8 — hazard
        let rep = Verifier::with_externals(ext).verify(&p);
        assert_eq!(rep.count(DiagKind::TypeMismatch), 2, "{}", rep.render_diagnostics());
        assert!(!rep.passes_deny());
        let d = &rep.diagnostics[0];
        assert_eq!(d.at, 1, "anchored to the reading instruction");
        assert!(d.message.contains("#0"), "names the writing instruction: {}", d.message);
        // A convert in between makes the same read clean.
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1));
        p.push(Instruction::new("VCVTPT162PT8", v(4), vec![v(2)]));
        p.push(fp("VMULPT8", 3, 4, 4));
        let rep = Verifier::with_externals(ext).verify(&p);
        assert!(rep.passes_deny(), "{}", rep.render_diagnostics());
        assert_eq!(rep.mix.converts, 1);
    }

    /// Saturating-encode stores read back as the plain spec are NOT a
    /// mismatch (the VCVTPH2HF8S / VCVTHF82PH round trip).
    #[test]
    fn saturating_and_plain_minifloat_are_compatible() {
        let mut ext = Externals::new();
        ext.load(0, 0, LaneType::MiniSat(E4M3));
        let mut p = Program::default();
        p.push(Instruction::new("VCVTHF82PH", v(1), vec![v(0)])); // reads Mini(E4M3)
        let rep = Verifier::with_externals(ext).verify(&p);
        assert_eq!(rep.count(DiagKind::TypeMismatch), 0, "{}", rep.render_diagnostics());
    }

    #[test]
    fn detects_use_before_def() {
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1)); // v0, v1 never defined
        let rep = Verifier::new().verify(&p);
        assert_eq!(rep.count(DiagKind::UseBeforeDef), 2, "{}", rep.render_diagnostics());
        assert!(!rep.passes_deny());
        // Implicit-inputs mode (lifter semantics: undefined registers are
        // architectural zeros) accepts the same program.
        let rep = Verifier::new().implicit_inputs(true).verify(&p);
        assert!(rep.is_clean(), "{}", rep.render_diagnostics());
        // An external journal entry also satisfies the definition.
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        let rep = Verifier::with_externals(ext).verify(&p);
        assert!(rep.is_clean(), "{}", rep.render_diagnostics());
    }

    #[test]
    fn detects_dead_write() {
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1)); // #0: never read …
        p.push(fp("VMULPT16", 2, 0, 1)); // #1: … clobbered here
        p.push(fp("VSUBPT16", 3, 2, 0)); // #2: keeps #1 live
        let rep = Verifier::with_externals(ext).verify(&p);
        assert_eq!(rep.count(DiagKind::DeadWrite), 1, "{}", rep.render_diagnostics());
        // Dead writes are warnings: wasteful, not value-corrupting.
        assert!(rep.passes_deny());
        assert_eq!(rep.error_count(), 0);
        assert_eq!(rep.warning_count(), 1);
        let d = &rep.diagnostics[0];
        assert!(d.message.contains("#0") && d.message.contains("#1"), "{}", d.message);
        // A merging masked overwrite reads the old value: not dead.
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        ext.set_mask(0, 1);
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1));
        p.push(fp("VMULPT16", 2, 0, 1).with_mask(1, false));
        p.push(fp("VSUBPT16", 3, 2, 0));
        let rep = Verifier::with_externals(ext).verify(&p);
        assert_eq!(rep.count(DiagKind::DeadWrite), 0, "{}", rep.render_diagnostics());
    }

    /// A journalled harness read keeps the write live: write → data-I/O
    /// read → overwrite is the per-tile store/read-back pattern of every
    /// kernel, not a dead write.
    #[test]
    fn journalled_harness_read_keeps_write_live() {
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1)); // #0: tile result …
        p.push(fp("VMULPT16", 2, 0, 1)); // #1: … next tile clobbers
        // Without the read journal the overwrite at #1 is a dead write.
        let rep = Verifier::with_externals(ext.clone()).verify(&p);
        assert_eq!(rep.count(DiagKind::DeadWrite), 1, "{}", rep.render_diagnostics());
        // With the harness read of v2 journalled between #0 and #1 it is
        // a consumed value.
        ext.read(1, 2);
        let rep = Verifier::with_externals(ext).verify(&p);
        assert!(rep.is_clean(), "{}", rep.render_diagnostics());
    }

    /// End-of-program writes are harness outputs, never flagged dead.
    #[test]
    fn final_writes_are_not_dead() {
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1));
        let rep = Verifier::with_externals(ext).verify(&p);
        assert!(rep.is_clean(), "{}", rep.render_diagnostics());
    }

    #[test]
    fn detects_unset_mask() {
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1).with_mask(5, true)); // k5 never set
        let rep = Verifier::with_externals(ext).verify(&p);
        assert_eq!(rep.count(DiagKind::UnsetMask), 1, "{}", rep.render_diagnostics());
        assert!(!rep.passes_deny());
        assert!(rep.diagnostics[0].message.contains("k5"));
        // Unset masks are errors even under implicit-inputs (an all-zero
        // mask silently drops every lane).
        let rep = Verifier::new().implicit_inputs(true).verify(&p);
        assert_eq!(rep.count(DiagKind::UnsetMask), 1);
        // k0 is "no mask": always fine.
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1).with_mask(0, false));
        let rep = Verifier::new().implicit_inputs(true).verify(&p);
        assert!(rep.is_clean(), "{}", rep.render_diagnostics());
        // A compare defines the mask; using it afterwards is clean.
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        let mut p = Program::default();
        p.push(Instruction::new(
            "VCMPPT16",
            Operand::Kreg(5),
            vec![v(0), v(1), Operand::Imm(1)],
        ));
        p.push(fp("VADDPT16", 2, 0, 1).with_mask(5, true));
        let rep = Verifier::with_externals(ext).verify(&p);
        assert!(rep.is_clean(), "{}", rep.render_diagnostics());
    }

    #[test]
    fn detects_irregular_mnemonic() {
        let mut p = Program::default();
        p.push(Instruction::new("VFROBNICATE", v(0), vec![v(1)]));
        let rep = Verifier::new().implicit_inputs(true).verify(&p);
        assert_eq!(rep.count(DiagKind::IrregularMnemonic), 1, "{}", rep.render_diagnostics());
        assert!(!rep.passes_deny());
        assert_eq!(rep.diagnostics[0].at, 0);
        assert!(rep.diagnostics[0].message.contains("VFROBNICATE"));
        // Operand shape that cannot fit the plan is the same class:
        // a mask op with a vector destination.
        let mut p = Program::default();
        p.push(Instruction::new("KANDQ", v(0), vec![Operand::Kreg(1), Operand::Kreg(2)]));
        let rep = Verifier::new().implicit_inputs(true).verify(&p);
        assert!(rep.count(DiagKind::IrregularMnemonic) >= 1, "{}", rep.render_diagnostics());
    }

    /// Position-aware externals: a mid-program reload changes the type a
    /// register may be read at from that index on.
    #[test]
    fn externals_apply_at_their_instruction_index() {
        let mut ext = Externals::new();
        ext.load(0, 0, T16);
        ext.load(0, 1, T16);
        ext.load(1, 0, T8); // reloaded as t8 before #1
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1)); // #0: v0 still t16 — clean
        p.push(fp("VADDPT8", 3, 0, 0)); // #1: v0 now t8 — clean
        p.push(fp("VADDPT16", 4, 0, 0)); // #2: v0 is t8 — hazard ×2 reads
        let rep = Verifier::with_externals(ext).verify(&p);
        assert_eq!(rep.count(DiagKind::TypeMismatch), 2, "{}", rep.render_diagnostics());
        assert!(rep.diagnostics.iter().all(|d| d.at == 2));
    }

    /// The accumulator of a dot product is a read: a preceding write to
    /// it is live, and its type is checked at the destination type.
    #[test]
    fn dot_reads_its_accumulator() {
        let mut ext = Externals::new();
        ext.load(0, 0, T8);
        ext.load(0, 1, T8);
        ext.load(0, 2, T16);
        let mut p = Program::default();
        p.push(Instruction::new("VDPPT8PT16", v(2), vec![v(0), v(1)]));
        let rep = Verifier::with_externals(ext).verify(&p);
        assert!(rep.is_clean(), "{}", rep.render_diagnostics());
        assert_eq!(rep.mix.dots, 1);
        // Accumulator held at the wrong type → mismatch.
        let mut ext = Externals::new();
        ext.load(0, 0, T8);
        ext.load(0, 1, T8);
        ext.load(0, 2, T8);
        let rep = Verifier::with_externals(ext).verify(&p);
        assert_eq!(rep.count(DiagKind::TypeMismatch), 1, "{}", rep.render_diagnostics());
    }

    /// The static mix equals the program's own histogram by construction.
    #[test]
    fn static_mix_matches_program_histogram() {
        let mut p = Program::default();
        p.push(fp("VADDPT16", 2, 0, 1));
        p.push(fp("VADDPT16", 3, 2, 1));
        p.push(Instruction::new("VCVTPT162PT8", v(4), vec![v(3)]));
        let rep = Verifier::new().implicit_inputs(true).verify(&p);
        assert_eq!(rep.mix.total, 3);
        assert_eq!(rep.mix.converts, 1);
        assert_eq!(rep.mix.histogram, p.histogram());
    }
}
