//! The declarative rewrite-rule table over the HLO-lite [`Graph`] node
//! set.
//!
//! Every rule is a pure pattern: `fn(&Graph, NodeId) -> Option<Rewrite>`
//! — it inspects one node (whose operands the driver has already
//! resolved through this iteration's alias table) and either proposes a
//! rewrite or declines. Rules never allocate graph nodes: a [`Rewrite`]
//! either **aliases** the node to an existing earlier node or
//! **replaces** its body in place with one referencing only existing
//! earlier nodes, which is what keeps the graph topologically ordered
//! (operands always precede users) without a rebuild.
//!
//! ## Soundness contract
//!
//! The graph evaluates over f64 planes; a `Convert` node is the
//! quantisation `decode ∘ encode` at a lane type, and **quantisation is
//! idempotent**: re-encoding a representable value reproduces its bits
//! exactly (property-tested per format in [`crate::sim::lanes`]). Each
//! rule's doc comment states the exact identity it relies on. Rules come
//! in two tiers:
//!
//! * **Exact** ([`RuleSet::exact`]) — the rewritten graph evaluates to
//!   the *bit-identical* planes of the original on every input,
//!   NaN/±inf/±0 lanes included. This is the tier the engine's
//!   optimize-then-lower path uses, because the lowered program is
//!   pinned bit-identical to direct machine execution (the
//!   `optimized_lowering_bit_identity` fuzz axis).
//! * **Contractive** ([`RuleSet::all`] adds these) — value-changing
//!   contractions that *reduce* rounding steps (`Mul`+`Add` → single
//!   -rounding `Fma`, accumulator folding into a widening `Dot`). They
//!   are sound as precision *improvements* for graph-interpreter
//!   workloads but are excluded from the bit-identity path by
//!   construction.
//!
//! One NaN note applies to every value-returning alias rule (`x·1`,
//! `x±0`): aliasing hands downstream consumers the original NaN operand
//! where the arithmetic might have produced a NaN with a different
//! payload. All of the graph's observation channels are
//! payload-insensitive — every register write re-encodes (and every
//! codec canonicalises its NaN pattern), and the plane arithmetic only
//! propagates NaN-ness — so the alias is unobservable; the rules below
//! additionally demand *bit-exact* constants wherever constant planes
//! are compared, so no rule ever fires on a payload it cannot prove.

use crate::num::NanStyle;
use crate::sim::graph::{BinOp, Graph, Node, NodeId};
use crate::sim::lanes::LaneType;

/// The action a rule proposes for a matched node.
pub enum Rewrite {
    /// Every use of the matched node is redirected to this existing
    /// (earlier) node; the matched node goes dead.
    Alias(NodeId),
    /// The matched node's body is replaced in place. The new body may
    /// only reference nodes that precede the matched node (all rule
    /// replacements reference operands of the matched subtree, which do
    /// by construction).
    Replace(Node),
}

/// One rewrite rule: a stable name (telemetry counters are keyed
/// `opt.rule.<name>.applied`), the exactness tier, and the matcher.
pub struct Rule {
    pub name: &'static str,
    /// `true`: bit-identity preserving. `false`: contractive
    /// (rounding-reducing, value-changing).
    pub exact: bool,
    pub apply: fn(&Graph, NodeId) -> Option<Rewrite>,
}

/// An ordered rule table (first matching rule wins per node per
/// iteration). The driver additionally runs structural CSE, reported
/// under the reserved name [`CSE_RULE`].
pub struct RuleSet {
    rules: Vec<Rule>,
}

/// Reserved per-rule report name for the driver-integrated CSE pass.
pub const CSE_RULE: &str = "cse";

/// The full rule table, in application order. Exact rules first.
const TABLE: &[Rule] = &[
    Rule { name: "convert-fold", exact: true, apply: convert_fold },
    Rule { name: "convert-widen", exact: true, apply: convert_widen },
    Rule { name: "mul-one", exact: true, apply: mul_one },
    Rule { name: "add-zero", exact: true, apply: add_zero },
    Rule { name: "mul-zero", exact: true, apply: mul_zero },
    Rule { name: "dead-select", exact: true, apply: dead_select },
    Rule { name: "select-same", exact: true, apply: select_same },
    Rule { name: "fma-fuse", exact: false, apply: fma_fuse },
    Rule { name: "dot-widen", exact: false, apply: dot_widen },
];

impl RuleSet {
    /// Only the bit-identity-preserving rules — the engine path.
    pub fn exact() -> RuleSet {
        RuleSet { rules: TABLE.iter().filter(|r| r.exact).map(clone_rule).collect() }
    }

    /// Exact + contractive rules — interpreter-only workloads.
    pub fn all() -> RuleSet {
        RuleSet { rules: TABLE.iter().map(clone_rule).collect() }
    }

    pub fn rules(&self) -> &[Rule] {
        &self.rules
    }

    /// Every rule name this set can report (CSE included) — the
    /// telemetry registry pre-seeds its per-rule counters from this.
    pub fn names(&self) -> Vec<&'static str> {
        let mut n: Vec<&'static str> = self.rules.iter().map(|r| r.name).collect();
        n.push(CSE_RULE);
        n
    }
}

fn clone_rule(r: &Rule) -> Rule {
    Rule { name: r.name, exact: r.exact, apply: r.apply }
}

// ---------------------------------------------------------------------------
// Exact rules
// ---------------------------------------------------------------------------

/// `Convert(x, T)` where `x` already produces a `T`-quantised plane, or
/// where `x` is a constant plane every lane of which round-trips
/// bit-exactly through `T`.
///
/// **Soundness (exact):** quantisation idempotence —
/// `q_T(q_T(x)) = q_T(x)` per lane, bit for bit. The constant arm
/// demands `decode(encode(lane)).to_bits() == lane.to_bits()` for all 64
/// lanes, so it cannot fire on a constant the quantisation would move
/// (NaN payloads included: a constant with a non-canonical payload
/// simply fails the bit check).
fn convert_fold(g: &Graph, id: NodeId) -> Option<Rewrite> {
    let Node::Convert { src, ty } = *g.node(id) else { return None };
    if g.quantised_ty(src) == Some(ty) {
        return Some(Rewrite::Alias(src));
    }
    if let Node::Const(p) = g.node(src) {
        let exact = p.iter().all(|&x| ty.decode(ty.encode(x)).to_bits() == x.to_bits());
        if exact {
            return Some(Rewrite::Alias(src));
        }
    }
    None
}

/// `Convert(x, W)` where `x` is provably quantised at `T` and every
/// value of `T` is exactly representable in `W` (a lossless embedding):
/// the convert is the identity.
///
/// **Soundness (exact):** `q_W` restricted to `range(q_T)` is the
/// identity when `T ⊆ W` value-wise. The embedding table is
/// deliberately same-family:
/// * `Takum(n) ⊆ Takum(m)` for `n ≤ m` — takum is a prefix code, every
///   shorter encoding is a truncation of a longer one
///   (property-tested exhaustively in `rust/tests/opt.rs`).
/// * IEEE-style minifloats embed when the target has at least as many
///   exponent bits, at least as many mantissa bits and at least the
///   bias (every finite source value, subnormals included, is exact in
///   the target; `±inf → ±inf`, NaN → NaN). A **saturating** target
///   (`MiniSat`) additionally requires an inf-free source — saturating
///   encode clamps `±inf` to max-finite, which would change the value.
///   Saturating and `Fn`-style (E4M3) *sources* are inf-free by
///   construction.
///
/// This is the rule that erases the OFP8 storage↔compute convert tax:
/// `Convert(F16, x@e4m3)` chains (the `cvt_in` half of every OFP8
/// kernel cell) fold to nothing, while takum cells are already at the
/// fixpoint — the paper's headline, made measurable in
/// `BENCH_kernels.json`.
fn convert_widen(g: &Graph, id: NodeId) -> Option<Rewrite> {
    let Node::Convert { src, ty } = *g.node(id) else { return None };
    let t = g.quantised_ty(src)?;
    if t != ty && losslessly_embeds(t, ty) {
        return Some(Rewrite::Alias(src));
    }
    None
}

/// Whether every value producible by quantising through `t` is exactly
/// representable (same value, canonical bits) under `w`'s quantisation.
pub(crate) fn losslessly_embeds(t: LaneType, w: LaneType) -> bool {
    use LaneType::*;
    match (t, w) {
        (Takum(n), Takum(m)) => n <= m,
        (Mini(s), Mini(d)) => spec_embeds(s, d),
        (MiniSat(s), Mini(d)) => spec_embeds(s, d),
        // Saturating targets clamp ±inf to max-finite: only inf-free
        // sources embed (Fn-style has no inf encoding; saturating
        // quantisation never produces one).
        (Mini(s), MiniSat(d)) => s.nan == NanStyle::Fn && spec_embeds(s, d),
        (MiniSat(s), MiniSat(d)) => spec_embeds(s, d),
        _ => false,
    }
}

fn spec_embeds(s: crate::num::MinifloatSpec, d: crate::num::MinifloatSpec) -> bool {
    d.exp_bits >= s.exp_bits && d.man_bits >= s.man_bits && d.bias >= s.bias
}

/// `x · 1 → x` (either side).
///
/// **Soundness (exact):** `x · 1.0 == x` bit-exactly for every f64,
/// signed zeros (`-0 · 1 = -0`), infinities and NaN-ness included. The
/// constant must be all-lanes `1.0` *bit*-exact.
fn mul_one(g: &Graph, id: NodeId) -> Option<Rewrite> {
    let Node::Bin { op: BinOp::Mul, a, b } = *g.node(id) else { return None };
    if const_all_bits(g, a, 1.0f64.to_bits()) {
        return Some(Rewrite::Alias(b));
    }
    if const_all_bits(g, b, 1.0f64.to_bits()) {
        return Some(Rewrite::Alias(a));
    }
    None
}

/// `x + (-0) → x` (either side) and `x - (+0) → x` (second operand).
///
/// **Soundness (exact):** `-0.0` is the additive identity under
/// round-to-nearest: `x + (-0.0) == x` bit-exactly for every x —
/// including `x = +0.0` (`+0 + -0 = +0`) and `x = -0.0`
/// (`-0 + -0 = -0`). `+0.0` is **not** (`-0 + +0 = +0` flips the zero
/// sign), which is why the Add arm demands the `-0.0` bit pattern.
/// Symmetrically `x - (+0.0) == x` (`-0 - +0 = -0`, `+0 - +0 = +0`).
fn add_zero(g: &Graph, id: NodeId) -> Option<Rewrite> {
    match *g.node(id) {
        Node::Bin { op: BinOp::Add, a, b } => {
            let neg0 = (-0.0f64).to_bits();
            if const_all_bits(g, a, neg0) {
                return Some(Rewrite::Alias(b));
            }
            if const_all_bits(g, b, neg0) {
                return Some(Rewrite::Alias(a));
            }
            None
        }
        Node::Bin { op: BinOp::Sub, a, b } => {
            const_all_bits(g, b, 0.0f64.to_bits()).then_some(Rewrite::Alias(a))
        }
        _ => None,
    }
}

/// `c0 · c → Const(c0 · c)` where `c0` is an all-`±0.0` constant and
/// `c` a constant with **all-finite** lanes — the finite-lane proof.
///
/// **Soundness (exact):** computed lane-wise at fold time with the very
/// multiplication the evaluator would perform, so signed zeros come out
/// right (`+0 · -x = -0`). The finite-lane demand is load-bearing:
/// `±inf · 0 = NaN` and `NaN · 0 = NaN`, so a lane that is not provably
/// finite blocks the fold.
fn mul_zero(g: &Graph, id: NodeId) -> Option<Rewrite> {
    let Node::Bin { op: BinOp::Mul, a, b } = *g.node(id) else { return None };
    let zero_side = |n: NodeId| match g.node(n) {
        Node::Const(p) => p.iter().all(|x| *x == 0.0).then_some(p),
        _ => None,
    };
    let finite_side = |n: NodeId| match g.node(n) {
        Node::Const(p) => p.iter().all(|x| x.is_finite()).then_some(p),
        _ => None,
    };
    let (z, c) = if let (Some(z), Some(c)) = (zero_side(a), finite_side(b)) {
        (z, c)
    } else if let (Some(z), Some(c)) = (zero_side(b), finite_side(a)) {
        (z, c)
    } else {
        return None;
    };
    let mut out = [0.0f64; 64];
    for i in 0..64 {
        out[i] = z[i] * c[i];
    }
    Some(Rewrite::Replace(Node::Const(Box::new(out))))
}

/// `Select(mask, a, b)` with a statically all-set mask → `a`; all-clear
/// → `b`.
///
/// **Soundness (exact):** the Select evaluator is a pure lane mux; a
/// constant mask of `u64::MAX` selects every lane from `a`, `0` every
/// lane from `b`. Masks are baked into the node at lift time (the
/// lifted subset cannot write mask registers), so the staticness is
/// structural, not an approximation.
fn dead_select(g: &Graph, id: NodeId) -> Option<Rewrite> {
    let Node::Select { mask, a, b } = *g.node(id) else { return None };
    if mask == u64::MAX {
        return Some(Rewrite::Alias(a));
    }
    if mask == 0 {
        return Some(Rewrite::Alias(b));
    }
    None
}

/// `Select(_, a, a) → a` — both arms identical (commonly exposed by CSE
/// merging the arms first).
///
/// **Soundness (exact):** the mux of a plane with itself is that plane,
/// whatever the mask.
fn select_same(g: &Graph, id: NodeId) -> Option<Rewrite> {
    let Node::Select { a, b, .. } = *g.node(id) else { return None };
    (a == b).then_some(Rewrite::Alias(a))
}

// ---------------------------------------------------------------------------
// Contractive rules (value-changing: fewer roundings)
// ---------------------------------------------------------------------------

/// `Mul(a,b) + z → Fma(a,b,z)` (the `Bin(Mul)+Bin(Add)→Fma` fusion;
/// composed under a `Convert`, this is the `Convert(Fma(..))` shape).
///
/// **Soundness (contractive):** `fma(a,b,z)` rounds once where
/// `(a·b)+z` rounds twice — the values differ by at most the eliminated
/// intermediate rounding, always toward the infinitely precise result.
/// Value-changing, therefore excluded from [`RuleSet::exact`] and from
/// the engine's bit-identity path.
fn fma_fuse(g: &Graph, id: NodeId) -> Option<Rewrite> {
    let Node::Bin { op: BinOp::Add, a, b } = *g.node(id) else { return None };
    let as_mul = |n: NodeId| match *g.node(n) {
        Node::Bin { op: BinOp::Mul, a, b } => Some((a, b)),
        _ => None,
    };
    let (ma, mb, z) = if let Some((ma, mb)) = as_mul(a) {
        (ma, mb, b)
    } else if let Some((ma, mb)) = as_mul(b) {
        (ma, mb, a)
    } else {
        return None;
    };
    use crate::sim::lanes::{FmaKind, FmaOrder};
    Some(Rewrite::Replace(Node::Fma {
        kind: FmaKind::Madd,
        order: FmaOrder::O213,
        a: ma,
        b: mb,
        z,
    }))
}

/// `Dot(a, b, 0) + w → Dot(a, b, w)` — fold a post-add into the widening
/// dot's accumulator when the accumulator is statically zero.
///
/// **Soundness (contractive):** the dot evaluator folds left-to-right
/// (`((z + p₀) + p₁)`), so moving `w` into the accumulator slot changes
/// the association order (`((w + p₀) + p₁)` vs `((0 + p₀) + p₁) + w`) —
/// same terms, one fewer add and a different rounding path.
/// Value-changing, therefore contractive-tier only.
fn dot_widen(g: &Graph, id: NodeId) -> Option<Rewrite> {
    let Node::Bin { op: BinOp::Add, a, b } = *g.node(id) else { return None };
    let as_zero_dot = |n: NodeId| match *g.node(n) {
        Node::Dot { a, b, z } if const_all_bits(g, z, 0.0f64.to_bits()) => Some((a, b)),
        _ => None,
    };
    let (da, db, w) = if let Some((da, db)) = as_zero_dot(a) {
        (da, db, b)
    } else if let Some((da, db)) = as_zero_dot(b) {
        (da, db, a)
    } else {
        return None;
    };
    Some(Rewrite::Replace(Node::Dot { a: da, b: db, z: w }))
}

/// Whether `n` is a `Const` whose every lane is exactly `bits`.
fn const_all_bits(g: &Graph, n: NodeId, bits: u64) -> bool {
    match g.node(n) {
        Node::Const(p) => p.iter().all(|x| x.to_bits() == bits),
        _ => false,
    }
}
