//! Graph → [`Program`] lowering: re-emit an optimized dataflow graph as
//! an executable instruction stream for the vector backend.
//!
//! ## Contract
//!
//! [`lower`] consumes a lifted (and usually optimized) [`Graph`] plus the
//! *initial* [`RegisterFile`] the original program ran against, and
//! produces a [`Lowered`] bundle: the instruction stream, a harness-load
//! journal for materialized constants, and the register outputs. Running
//! the bundle with [`run_lowered`] on a machine whose registers start in
//! that same initial state leaves the register file **bit-identical** to
//! a direct replay of the original program — the differential-fuzz suite
//! pins this across every `Backend × CodecMode` config.
//!
//! ## Invariants the emitter maintains
//!
//! 1. **Home invariant.** Every materialized node `N` has a *home*
//!    `(r, T)` such that register `r` holds exactly `encode_T(plane(N))`
//!    over the full register — including merge-base bits beyond a masked
//!    write's range, which the graph models with nested `Select`s.
//! 2. **Operand exactness.** An operand demanded at type `W` when homed
//!    at `T ≠ W` is rematerialized with a widening `VCVT` only when the
//!    home is decode-exact (`quantised_ty == Some(T)`) and `T` embeds
//!    losslessly in `W` — exactly the precondition under which the
//!    `convert-widen` rule created the cross-type use, so the
//!    rematerialized register decodes to the identical plane.
//! 3. **Mask reconstruction.** A partial write mask is only ever
//!    re-emitted as `{k}` against the *initial* mask-register state, at
//!    the same lane range the original instruction used — lifted
//!    programs cannot write mask registers, so the original `k` still
//!    matches. `k0` is architecturally "no mask" and is never chosen.
//! 4. **Scratch discipline.** Scratch registers are linearly allocated
//!    against last-use indices and never collide with pinned input
//!    registers or with live homes; [`run_lowered`] restores every
//!    non-output register afterwards, so scratch traffic is invisible in
//!    the final state.
//!
//! Anything outside these invariants (a `Param`/`Reduce` demanded as a
//! register value, an unquantised cross-type use, a write mask no
//! initial `k` reproduces, register pressure beyond the 32-register
//! file) makes the graph *not lowerable*: [`lower`] returns `Err` and
//! the caller falls back to direct execution — lowering is an
//! optimization, never an obligation.

use std::collections::HashMap;

use anyhow::{anyhow, bail, ensure, Result};

use crate::sim::exec::Machine;
use crate::sim::graph::{BinOp, Graph, LoadEvent, Node, NodeId, Plane, RegOutput};
use crate::sim::lanes::{FmaKind, FmaOrder, LaneType};
use crate::sim::program::{Instruction, Operand, Program};
use crate::sim::register::{RegisterFile, VecReg, NUM_MASKS, NUM_VREGS};
use crate::verify::{Externals, Report, Verifier};

use super::rules::losslessly_embeds;

/// A lowered graph: the instruction stream plus everything needed to run
/// and verify it.
#[derive(Debug, Clone)]
pub struct Lowered {
    /// The emitted instruction stream.
    pub prog: Program,
    /// Harness-side constant loads, `at` nondecreasing: event `i` is
    /// applied before executing instruction `loads[i].at`.
    pub loads: Vec<LoadEvent>,
    /// Registers the lowered program defines as outputs (the original
    /// program's written registers). Every other register is restored by
    /// [`run_lowered`].
    pub output_regs: Vec<u8>,
    /// Input registers read from the initial machine state, with the
    /// lane type(s) they are read at.
    initial_reads: Vec<(u8, LaneType)>,
    /// Mask registers referenced by emitted `{k}` suffixes.
    kregs: Vec<u8>,
}

impl Lowered {
    /// The external-load journal for the static verifier: initial-state
    /// register reads at position 0, constant materializations at their
    /// emission sites, mask registers as externally set.
    pub fn externals(&self) -> Externals {
        let mut e = Externals::new();
        let mut by_reg: HashMap<u8, Vec<LaneType>> = HashMap::new();
        for (reg, ty) in &self.initial_reads {
            let tys = by_reg.entry(*reg).or_default();
            if !tys.contains(ty) {
                tys.push(*ty);
            }
        }
        for (reg, tys) in by_reg {
            // A register read at two types (legal for unwritten inputs)
            // journals untyped, i.e. readable at any lane type.
            match tys.as_slice() {
                [ty] => e.load(0, reg, *ty),
                _ => e.load_untyped(0, reg),
            }
        }
        for ev in &self.loads {
            e.load(ev.at, ev.reg, ev.ty);
        }
        for &k in &self.kregs {
            e.set_mask(0, k);
        }
        e
    }

    /// Verify the lowered program under its own externals journal (the
    /// engine's `Verify::Deny` gate runs exactly this).
    pub fn verify(&self) -> Report {
        Verifier::with_externals(self.externals()).implicit_inputs(true).verify(&self.prog)
    }
}

/// Execute a lowered bundle on `m`, whose vector *and* mask registers
/// must be in the initial state that was given to [`lower`]. Interleaves
/// the constant-load journal at its recorded positions and afterwards
/// restores every register not in [`Lowered::output_regs`], so the final
/// register file is bit-identical to a direct replay of the source
/// program.
pub fn run_lowered(m: &mut Machine, low: &Lowered) -> Result<()> {
    let saved = m.regs.v;
    let mut next = 0usize;
    for (at, ins) in low.prog.instrs.iter().enumerate() {
        while next < low.loads.len() && low.loads[next].at <= at {
            let ev = &low.loads[next];
            m.load_f64(ev.reg, ev.ty, &ev.values);
            next += 1;
        }
        m.step(ins)?;
    }
    for ev in &low.loads[next..] {
        m.load_f64(ev.reg, ev.ty, &ev.values);
    }
    for (r, reg) in saved.iter().enumerate() {
        if !low.output_regs.contains(&(r as u8)) {
            m.regs.v[r] = *reg;
        }
    }
    Ok(())
}

/// Lower `g` to an executable program against the initial register state
/// `init` (vector registers for input homes, mask registers for `{k}`
/// reconstruction). Errors are graceful "not lowerable" verdicts — the
/// caller falls back to direct execution.
pub fn lower(g: &Graph, init: &RegisterFile) -> Result<Lowered> {
    ensure!(
        g.returns().is_empty(),
        "not lowerable: graph carries plane returns (readback artifact graph)"
    );
    ensure!(!g.outputs().is_empty(), "not lowerable: graph has no register outputs");
    let n = g.len();
    let mut lw = Lowerer {
        g,
        init,
        prog: Program::default(),
        loads: Vec::new(),
        uses: vec![0; n],
        last_use: vec![0; n],
        home_needed: vec![false; n],
        inline_op: vec![false; n],
        skip: vec![false; n],
        stype: vec![None; n],
        target: HashMap::new(),
        home: vec![None; n],
        alts: Vec::new(),
        pinned: [false; NUM_VREGS],
        release: [None; NUM_VREGS],
        cursor: 0,
        epilogue: false,
        kregs_used: [false; NUM_MASKS],
        initial_reads: Vec::new(),
    };
    lw.prepare()?;
    lw.emit_all()?;
    let output_regs = lw.epilogue()?;
    let kregs = (0..NUM_MASKS as u8).filter(|&k| lw.kregs_used[k as usize]).collect();
    Ok(Lowered {
        prog: lw.prog,
        loads: lw.loads,
        output_regs,
        initial_reads: lw.initial_reads,
        kregs,
    })
}

// ---------------------------------------------------------------------------
// The emitter
// ---------------------------------------------------------------------------

/// What a `Select` payload is, for range/mnemonic selection.
enum Payload {
    /// A raw arithmetic node — emitted directly as a masked op.
    Raw,
    /// A quantised value at the given type — emitted as a masked `VCVT`.
    Quant(LaneType),
    /// A constant plane — materialized and emitted as a masked
    /// self-`VMIN` move.
    Konst,
}

struct Lowerer<'a> {
    g: &'a Graph,
    init: &'a RegisterFile,
    prog: Program,
    loads: Vec<LoadEvent>,
    // -- analysis (prepare) --
    /// Consumer count per node (operand edges + register outputs).
    uses: Vec<u32>,
    /// Last node index that reads this node's register (outputs pin to
    /// `usize::MAX`). Select payloads/bases bump their operands to the
    /// select's index because emission is deferred to the select site.
    last_use: Vec<usize>,
    /// Node's value must live in a register (it is read as a register).
    home_needed: Vec<bool>,
    /// Raw node consumed only as a masked-select payload — emitted at
    /// the select site, never densely.
    inline_op: Vec<bool>,
    /// Inner zeroing-select consumed structurally by its outer select
    /// (re-emitted as a `{k}{z}` suffix, not an instruction).
    skip: Vec<bool>,
    /// Store type demanded of a raw node by its consumers (the lane type
    /// its register will be encoded at).
    stype: Vec<Option<LaneType>>,
    /// Preferred destination register per node index (its output reg).
    target: HashMap<usize, u8>,
    // -- emission state --
    /// `node → (register, store type)` once materialized.
    home: Vec<Option<(u8, LaneType)>>,
    /// Alternate materializations: `(node, type, register)` for constant
    /// loads and widening rematerializations. Linear scan — `LaneType`
    /// is not `Hash` and the list stays tiny.
    alts: Vec<(usize, LaneType, u8)>,
    /// Input registers (Load homes) — never allocated as scratch.
    pinned: [bool; NUM_VREGS],
    /// Per register: `None` = never used; `Some(i)` = free once the
    /// emission cursor passes `i` (`usize::MAX` = live forever).
    release: [Option<usize>; NUM_VREGS],
    cursor: usize,
    /// Epilogue mode: every allocation becomes permanent so output
    /// staging cannot be stolen.
    epilogue: bool,
    kregs_used: [bool; NUM_MASKS],
    initial_reads: Vec<(u8, LaneType)>,
}

impl<'a> Lowerer<'a> {
    // -- analysis ----------------------------------------------------------

    fn prepare(&mut self) -> Result<()> {
        let g = self.g;
        let n = g.len();
        // Forward: use counts and last-use indices. Select payload/base
        // operands are bumped to the select index (deferred emission).
        for i in 0..n {
            let node = g.node(NodeId::new(i));
            for op in node.operands().into_iter().flatten() {
                self.uses[op.idx()] += 1;
                self.last_use[op.idx()] = self.last_use[op.idx()].max(i);
            }
            if let Node::Select { a, b, .. } = node {
                if is_raw(g.node(*a)) {
                    for op in g.node(*a).operands().into_iter().flatten() {
                        self.last_use[op.idx()] = self.last_use[op.idx()].max(i);
                    }
                }
                if matches!(g.node(*b), Node::Select { .. }) {
                    for op in g.node(*b).operands().into_iter().flatten() {
                        self.last_use[op.idx()] = self.last_use[op.idx()].max(i);
                    }
                }
            }
        }
        for o in g.outputs() {
            let i = o.node.idx();
            self.uses[i] += 1;
            self.last_use[i] = usize::MAX;
            self.target.entry(i).or_insert(o.reg);
            if !matches!(g.node(o.node), Node::Const(_)) {
                self.home_needed[i] = true;
            }
            // The output tag is the store type for raw nodes and for
            // mixed (unquantised) selects; quantised nodes carry their
            // own type and a cross-tag output re-encodes in the
            // epilogue.
            match g.node(o.node) {
                Node::Bin { .. }
                | Node::RndScale { .. }
                | Node::Fma { .. }
                | Node::Dot { .. }
                | Node::Broadcast { .. } => self.set_stype(o.node, o.ty)?,
                Node::Select { .. } if g.quantised_ty(o.node).is_none() => {
                    self.set_stype(o.node, o.ty)?
                }
                _ => {}
            }
        }
        // Reverse: demand propagation. A node's flags are final before
        // its operands are visited (operands always precede users).
        for i in (0..n).rev() {
            if !self.home_needed[i] && !self.inline_op[i] {
                continue;
            }
            let id = NodeId::new(i);
            match g.node(id) {
                Node::Const(_) | Node::Param(_) | Node::Load { .. } => {}
                Node::Convert { src, ty } => {
                    let (src, ty) = (*src, *ty);
                    if !matches!(g.node(src), Node::Const(_)) {
                        self.home_needed[src.idx()] = true;
                    }
                    match g.node(src) {
                        Node::Bin { .. }
                        | Node::RndScale { .. }
                        | Node::Fma { .. }
                        | Node::Dot { .. }
                        | Node::Broadcast { .. } => self.set_stype(src, ty)?,
                        Node::Select { .. } if g.quantised_ty(src).is_none() => {
                            self.set_stype(src, ty)?
                        }
                        _ => {}
                    }
                }
                Node::Bin { a, b, .. } => self.mark_operands(&[*a, *b]),
                Node::RndScale { src, .. } | Node::Reduce { src, .. } => {
                    self.mark_operands(&[*src])
                }
                Node::Broadcast { src } => self.mark_operands(&[*src]),
                Node::Fma { a, b, z, .. } => self.mark_operands(&[*a, *b, *z]),
                Node::Dot { a, b, z } => self.mark_operands(&[*a, *b, *z]),
                Node::Select { mask, a, b } => {
                    let (wm, a, b) = (*mask, *a, *b);
                    let t = self.stype[i].or_else(|| g.quantised_ty(id));
                    match g.node(a) {
                        node if is_raw(node) => {
                            self.inline_op[a.idx()] = true;
                            if let Some(t) = t {
                                self.set_stype(a, t)?;
                            }
                        }
                        Node::Const(_) => {}
                        _ => self.home_needed[a.idx()] = true,
                    }
                    // A single-use inner select that zeroes disjoint
                    // lanes over an all-zero constant is the lifter's
                    // `{z}` pattern: consume it structurally.
                    let mut plain_base = true;
                    if let Node::Select { mask: m2, a: za, b: b2 } = g.node(b) {
                        if self.uses[b.idx()] == 1 && is_zero_const(g, *za) && m2 & wm == 0 {
                            self.skip[b.idx()] = true;
                            self.home_needed[b2.idx()] = true;
                            plain_base = false;
                        }
                    }
                    if plain_base && !matches!(g.node(b), Node::Const(_)) {
                        self.home_needed[b.idx()] = true;
                    }
                }
            }
        }
        Ok(())
    }

    fn mark_operands(&mut self, ops: &[NodeId]) {
        for &op in ops {
            if !matches!(self.g.node(op), Node::Const(_)) {
                self.home_needed[op.idx()] = true;
            }
        }
    }

    fn set_stype(&mut self, id: NodeId, t: LaneType) -> Result<()> {
        let slot = &mut self.stype[id.idx()];
        match *slot {
            None => {
                *slot = Some(t);
                Ok(())
            }
            Some(t0) if t0 == t => Ok(()),
            Some(t0) => bail!(
                "not lowerable: node {} demanded at both {t0:?} and {t:?}",
                id.idx()
            ),
        }
    }

    // -- register allocation -----------------------------------------------

    fn alloc(&mut self, release: usize, pref: Option<u8>) -> Result<u8> {
        let release = if self.epilogue { usize::MAX } else { release };
        for r in pref.into_iter().chain(0..NUM_VREGS as u8) {
            let ri = r as usize;
            if self.pinned[ri] {
                continue;
            }
            let free = match self.release[ri] {
                None => true,
                Some(rel) => rel != usize::MAX && rel < self.cursor,
            };
            if free {
                self.release[ri] = Some(release);
                return Ok(r);
            }
        }
        bail!("not lowerable: register pressure exceeds the vector register file")
    }

    /// Keep an aliased home alive until `until` (aliases share the
    /// source's register but may outlive its own last use).
    fn extend_release(&mut self, r: u8, until: usize) {
        if let Some(rel) = &mut self.release[r as usize] {
            *rel = (*rel).max(until);
        }
    }

    // -- instruction emission helpers --------------------------------------

    fn push_ins(
        &mut self,
        mnemonic: &str,
        d: u8,
        srcs: Vec<Operand>,
        mask: Option<u8>,
        zeroing: bool,
    ) {
        let mut ins = Instruction::new(mnemonic, Operand::Vreg(d), srcs);
        if let Some(k) = mask {
            ins = ins.with_mask(k, zeroing);
            self.kregs_used[k as usize] = true;
        }
        self.prog.push(ins);
    }

    /// Full-register move: `VMIN t, s, s` — `min(x, x) = x` lane-wise
    /// and re-encoding canonical register contents is the identity, so
    /// this is a bit-exact copy for every value the emitter produces.
    fn move_full(&mut self, d: u8, s: u8, t: LaneType) -> Result<()> {
        if d == s {
            return Ok(());
        }
        let sfx = packed_suffix(t)
            .ok_or_else(|| anyhow!("not lowerable: no packed move for {t:?}"))?;
        self.push_ins(
            &format!("VMIN{sfx}"),
            d,
            vec![Operand::Vreg(s), Operand::Vreg(s)],
            None,
            false,
        );
        Ok(())
    }

    /// Journal a constant plane as a harness load into `d` at `ty`.
    /// `strict` demands per-lane round-trip bit-exactness (a register
    /// *home* must decode back to the plane); output materialization
    /// only needs the encode, which matches by construction.
    fn load_const(&mut self, d: u8, ty: LaneType, plane: &Plane, strict: bool) -> Result<()> {
        let lanes = VecReg::lanes(ty.width());
        let values: Vec<f64> = plane[..lanes].to_vec();
        if strict {
            for (j, &v) in values.iter().enumerate() {
                let q = ty.decode(ty.encode(v));
                ensure!(
                    q.to_bits() == v.to_bits(),
                    "not lowerable: constant lane {j} ({v:e}) not representable at {ty:?}"
                );
            }
        }
        self.loads.push(LoadEvent { at: self.prog.len(), reg: d, ty, values });
        Ok(())
    }

    /// Register holding `plane(id)` encoded at `want`, such that
    /// decoding at `want` yields exactly `plane(id)`.
    fn operand_reg(&mut self, id: NodeId, want: LaneType) -> Result<u8> {
        let g = self.g;
        let i = id.idx();
        if let Some((r, t)) = self.home[i] {
            if t == want {
                return Ok(r);
            }
            if let Some(r2) = self.alt(i, want) {
                return Ok(r2);
            }
            // Widening rematerialization — sound under exactly the
            // `convert-widen` preconditions (see module invariant 2).
            ensure!(
                g.quantised_ty(id) == Some(t),
                "not lowerable: cross-type use of an unquantised value"
            );
            ensure!(
                losslessly_embeds(t, want),
                "not lowerable: {t:?} does not embed losslessly in {want:?}"
            );
            ensure!(
                VecReg::lanes(t.width().max(want.width())) == VecReg::lanes(want.width()),
                "not lowerable: narrowing rematerialization"
            );
            let d = self.alloc(self.last_use[i], None)?;
            let (ss, ds) = (must_packed(t)?, must_packed(want)?);
            self.push_ins(&format!("VCVT{ss}2{ds}"), d, vec![Operand::Vreg(r)], None, false);
            self.alts.push((i, want, d));
            Ok(d)
        } else if let Node::Const(p) = g.node(id) {
            if let Some(r2) = self.alt(i, want) {
                return Ok(r2);
            }
            let d = self.alloc(self.last_use[i], None)?;
            let plane = **p;
            self.load_const(d, want, &plane, true)?;
            self.alts.push((i, want, d));
            Ok(d)
        } else {
            bail!("internal lowering error: operand node {i} was never materialized")
        }
    }

    fn alt(&self, i: usize, want: LaneType) -> Option<u8> {
        self.alts.iter().find(|(j, ty, _)| *j == i && *ty == want).map(|(_, _, r)| *r)
    }

    /// Emit a raw arithmetic node into `d` at store type `t`. For
    /// masked emission (`mask`/`zeroing` from a select site),
    /// `merge_base` names the select base, which the caller has already
    /// moved into `d`; FMA/dot accumulators must coincide with it.
    fn emit_raw_into(
        &mut self,
        a: NodeId,
        t: LaneType,
        d: u8,
        mask: Option<u8>,
        zeroing: bool,
        scalar: bool,
        merge_base: Option<NodeId>,
    ) -> Result<()> {
        let g = self.g;
        match *g.node(a) {
            Node::Bin { op, a: x, b: y } => {
                let rx = self.operand_reg(x, t)?;
                let ry = self.operand_reg(y, t)?;
                let sfx = must_suffix(t, scalar)?;
                self.push_ins(
                    &format!("V{}{sfx}", bin_name(op)),
                    d,
                    vec![Operand::Vreg(rx), Operand::Vreg(ry)],
                    mask,
                    zeroing,
                );
            }
            Node::RndScale { src, m } => {
                let rs = self.operand_reg(src, t)?;
                let sfx = must_suffix(t, scalar)?;
                self.push_ins(
                    &format!("VRNDSCALE{sfx}"),
                    d,
                    vec![Operand::Vreg(rs), Operand::Imm(((m as i64) & 0xF) << 4)],
                    mask,
                    zeroing,
                );
            }
            Node::Fma { kind, order, a: x, b: y, z } => {
                let rx = self.operand_reg(x, t)?;
                let ry = self.operand_reg(y, t)?;
                match merge_base {
                    Some(base) => ensure!(
                        z == base,
                        "not lowerable: masked FMA accumulator differs from its merge base"
                    ),
                    None => {
                        let rz = self.operand_reg(z, t)?;
                        self.move_full(d, rz, t)?;
                    }
                }
                let sfx = must_suffix(t, scalar)?;
                let mn = format!("VF{}{}{sfx}", fma_name(kind), order_name(order));
                self.push_ins(&mn, d, vec![Operand::Vreg(rx), Operand::Vreg(ry)], mask, zeroing);
            }
            Node::Dot { a: x, b: y, z } => {
                ensure!(!scalar, "internal lowering error: scalar dot");
                let (s, mn) = self.dot_types(t, x, y)?;
                let rx = self.operand_reg(x, s)?;
                let ry = self.operand_reg(y, s)?;
                match merge_base {
                    Some(base) => ensure!(
                        z == base,
                        "not lowerable: masked dot accumulator differs from its merge base"
                    ),
                    None => {
                        let rz = self.operand_reg(z, t)?;
                        self.move_full(d, rz, t)?;
                    }
                }
                self.push_ins(&mn, d, vec![Operand::Vreg(rx), Operand::Vreg(ry)], mask, zeroing);
            }
            Node::Broadcast { src } => {
                let rs = self.operand_reg(src, t)?;
                self.push_ins(
                    &format!("VBROADCASTB{}", t.width()),
                    d,
                    vec![Operand::Vreg(rs)],
                    mask,
                    zeroing,
                );
            }
            _ => bail!("internal lowering error: emit_raw_into on a non-arithmetic node"),
        }
        Ok(())
    }

    /// Widening-dot source type and mnemonic for an accumulator at `t`.
    fn dot_types(&self, t: LaneType, x: NodeId, y: NodeId) -> Result<(LaneType, String)> {
        use crate::num::{BF16, F16};
        match t {
            LaneType::Takum(n) if n >= 16 => {
                Ok((LaneType::Takum(n / 2), format!("VDPPT{}PT{n}", n / 2)))
            }
            LaneType::Mini(spec) if spec.name == F32.name => {
                let cands =
                    [(LaneType::Mini(F16), "VDPPHPS"), (LaneType::Mini(BF16), "VDPBF16PS")];
                // Prefer the source type an operand is already
                // quantised at; otherwise any candidate both operands
                // embed into.
                let q = [self.g.quantised_ty(x), self.g.quantised_ty(y)];
                for (s, mn) in cands {
                    if q.iter().any(|qt| *qt == Some(s))
                        && self.dot_operand_ok(x, s)
                        && self.dot_operand_ok(y, s)
                    {
                        return Ok((s, mn.to_string()));
                    }
                }
                for (s, mn) in cands {
                    if self.dot_operand_ok(x, s) && self.dot_operand_ok(y, s) {
                        return Ok((s, mn.to_string()));
                    }
                }
                bail!("not lowerable: no widening-dot source type fits both operands")
            }
            _ => bail!("not lowerable: no dot instruction accumulates at {t:?}"),
        }
    }

    fn dot_operand_ok(&self, x: NodeId, s: LaneType) -> bool {
        match self.g.quantised_ty(x) {
            Some(t) => t == s || losslessly_embeds(t, s),
            // Constants are guarded per-lane at materialization.
            None => matches!(self.g.node(x), Node::Const(_)),
        }
    }

    // -- the forward emission pass -----------------------------------------

    fn emit_all(&mut self) -> Result<()> {
        let g = self.g;
        // Pin input homes: a Load node's value *is* its register.
        for i in 0..g.len() {
            if let Node::Load { reg, ty } = g.node(NodeId::new(i)) {
                if self.home_needed[i] {
                    self.pinned[*reg as usize] = true;
                    self.initial_reads.push((*reg, *ty));
                    self.home[i] = Some((*reg, *ty));
                }
            }
        }
        for i in 0..g.len() {
            self.cursor = i;
            if !self.home_needed[i] {
                continue;
            }
            let id = NodeId::new(i);
            match *g.node(id) {
                Node::Const(_) | Node::Load { .. } => {}
                Node::Param(_) => bail!("not lowerable: Param demanded as a register value"),
                Node::Reduce { .. } => {
                    bail!("not lowerable: Reduce has no register-level instruction")
                }
                Node::Convert { src, ty } => self.emit_convert(id, src, ty)?,
                Node::Select { mask, a, b } => self.emit_select(id, mask, a, b)?,
                _ => {
                    // Dense raw arithmetic.
                    let t = self.store_type(id)?;
                    let pref = self.target.get(&i).copied();
                    let d = self.alloc(self.last_use[i], pref)?;
                    self.emit_raw_into(id, t, d, None, false, false, None)?;
                    self.home[i] = Some((d, t));
                }
            }
        }
        Ok(())
    }

    fn store_type(&self, id: NodeId) -> Result<LaneType> {
        self.stype[id.idx()]
            .or_else(|| self.g.quantised_ty(id))
            .ok_or_else(|| {
                anyhow!("not lowerable: node {} has no recoverable store type", id.idx())
            })
    }

    fn emit_convert(&mut self, id: NodeId, src: NodeId, ty: LaneType) -> Result<()> {
        let g = self.g;
        let i = id.idx();
        if let Node::Const(p) = g.node(src) {
            // Quantise-then-load: the journal load encodes at `ty`,
            // which *is* the convert.
            let d = self.alloc(self.last_use[i], self.target.get(&i).copied())?;
            let plane: Plane = core::array::from_fn(|j| ty.decode(ty.encode(p[j])));
            self.load_const(d, ty, &plane, true)?;
            self.home[i] = Some((d, ty));
            return Ok(());
        }
        let (r, t) = self.home[src.idx()]
            .ok_or_else(|| anyhow!("internal lowering error: convert source has no home"))?;
        if t == ty {
            // Same-type quantisation of an encoded register is the
            // identity (idempotence) — alias the home.
            self.home[i] = Some((r, t));
            self.extend_release(r, self.last_use[i]);
            return Ok(());
        }
        // Cross-type: the machine convert computes
        // `encode_ty(decode_t(r))`, which equals `encode_ty(plane(src))`
        // exactly when the source home is decode-exact.
        ensure!(
            g.quantised_ty(src) == Some(t),
            "not lowerable: cross-type convert of an unquantised home"
        );
        ensure!(
            VecReg::lanes(t.width().max(ty.width())) == VecReg::lanes(ty.width()),
            "not lowerable: narrowing dense convert (lifted graphs never produce one)"
        );
        let d = self.alloc(self.last_use[i], self.target.get(&i).copied())?;
        let (ss, ds) = (must_packed(t)?, must_packed(ty)?);
        self.push_ins(&format!("VCVT{ss}2{ds}"), d, vec![Operand::Vreg(r)], None, false);
        self.home[i] = Some((d, ty));
        Ok(())
    }

    fn emit_select(&mut self, id: NodeId, wm: u64, a: NodeId, b: NodeId) -> Result<()> {
        let g = self.g;
        let i = id.idx();
        if self.skip[i] {
            return Ok(());
        }
        let t = self.store_type(id)?;
        let full_lanes = VecReg::lanes(t.width());
        // Zero pattern: a skipped inner select means `{z}` semantics
        // with the zeroed range forced to `m2 | wm`.
        let (base, zeroing, forced_all) = if self.skip[b.idx()] {
            match g.node(b) {
                Node::Select { mask: m2, b: b2, .. } => (*b2, true, Some(*m2 | wm)),
                _ => bail!("internal lowering error: skipped base is not a select"),
            }
        } else {
            (b, false, None)
        };
        let payload = match g.node(a) {
            node if is_raw(node) => Payload::Raw,
            Node::Const(_) => Payload::Konst,
            _ => Payload::Quant(g.quantised_ty(a).ok_or_else(|| {
                anyhow!("not lowerable: select payload is neither raw nor quantised")
            })?),
        };
        // Candidate emission ranges (lanes, scalar?) — the original
        // instruction's range is always among them, so its write mask is
        // reconstructible from the initial `k` state (invariant 3).
        let ranges: Vec<(usize, bool)> = match (&payload, g.node(a)) {
            (Payload::Raw, Node::Dot { .. }) | (Payload::Raw, Node::Broadcast { .. }) => {
                vec![(full_lanes, false)]
            }
            (Payload::Raw, _) => {
                let mut v = vec![(full_lanes, false)];
                if scalar_suffix(t).is_some() {
                    v.push((1, true));
                }
                v
            }
            (Payload::Quant(ta), _) => {
                vec![(VecReg::lanes(ta.width().max(t.width())), false)]
            }
            (Payload::Konst, _) => {
                let mut v = vec![(full_lanes, false)];
                if scalar_suffix(t).is_some() {
                    v.push((1, true));
                }
                v
            }
        };
        let mut picked = None;
        for (lanes, sc) in ranges {
            let rm = mask_bits(lanes);
            if let Some(all) = forced_all {
                if all != rm {
                    continue;
                }
            }
            if wm & !rm != 0 {
                continue;
            }
            if wm == rm {
                picked = Some((lanes, sc, None));
                break;
            }
            // k0 is architecturally "no mask" — never a partial mask.
            if let Some(k) =
                (1..NUM_MASKS as u8).find(|&k| self.init.k[k as usize] & rm == wm)
            {
                picked = Some((lanes, sc, Some(k)));
                break;
            }
        }
        let (lanes, scalar, kmask) = picked.ok_or_else(|| {
            anyhow!("not lowerable: no initial mask state reproduces write mask {wm:#x}")
        })?;
        let d = self.alloc(self.last_use[i], self.target.get(&i).copied())?;
        // The base must be in `d` unless the op densely covers the full
        // register — FMA/dot always need it (accumulator == base).
        let acc_op = matches!(g.node(a), Node::Fma { .. } | Node::Dot { .. });
        if !(lanes == full_lanes && wm == mask_bits(lanes)) || acc_op {
            let rb = self.operand_reg(base, t)?;
            self.move_full(d, rb, t)?;
        }
        match payload {
            Payload::Raw => self.emit_raw_into(a, t, d, kmask, zeroing, scalar, Some(base))?,
            Payload::Quant(ta) => {
                let rp = self.operand_reg(a, ta)?;
                let (ss, ds) = (must_packed(ta)?, must_packed(t)?);
                self.push_ins(
                    &format!("VCVT{ss}2{ds}"),
                    d,
                    vec![Operand::Vreg(rp)],
                    kmask,
                    zeroing,
                );
            }
            Payload::Konst => {
                let rp = self.operand_reg(a, t)?;
                let sfx = must_suffix(t, scalar)?;
                self.push_ins(
                    &format!("VMIN{sfx}"),
                    d,
                    vec![Operand::Vreg(rp), Operand::Vreg(rp)],
                    kmask,
                    zeroing,
                );
            }
        }
        self.home[i] = Some((d, t));
        Ok(())
    }

    // -- the epilogue: install outputs -------------------------------------

    fn epilogue(&mut self) -> Result<Vec<u8>> {
        let g = self.g;
        self.epilogue = true;
        self.cursor = usize::MAX;
        // Reserve output targets so staging copies and re-encode
        // converts never land in a register the final moves write.
        for o in g.outputs() {
            let t = o.reg as usize;
            if !self.pinned[t] && self.release[t] != Some(usize::MAX) {
                self.release[t] = Some(usize::MAX);
            }
        }
        let mut moves: Vec<(u8, u8, LaneType)> = Vec::new();
        let mut output_regs = Vec::new();
        for o in g.outputs() {
            let r = self.output_source(o)?;
            if r != o.reg {
                moves.push((o.reg, r, o.ty));
            }
            output_regs.push(o.reg);
        }
        // Stage sources that are themselves targets out of the way
        // before any final move clobbers them.
        let targets: Vec<u8> = moves.iter().map(|m| m.0).collect();
        for mv in &mut moves {
            if targets.contains(&mv.1) {
                let s = self.alloc(usize::MAX, None)?;
                let (src, ty) = (mv.1, mv.2);
                self.move_full(s, src, ty)?;
                mv.1 = s;
            }
        }
        for (tgt, src, ty) in moves {
            self.move_full(tgt, src, ty)?;
        }
        Ok(output_regs)
    }

    /// Register holding the bits the output demands:
    /// `encode_{o.ty}(plane(o.node))` over the full register. Unlike
    /// [`Self::operand_reg`] this is a *bits* demand — a cross-tag
    /// re-encode is the output's own quantisation, so no lossless-embed
    /// precondition applies.
    fn output_source(&mut self, o: &RegOutput) -> Result<u8> {
        let g = self.g;
        let i = o.node.idx();
        if let Some((r, t)) = self.home[i] {
            if t == o.ty {
                return Ok(r);
            }
            ensure!(
                g.quantised_ty(o.node) == Some(t),
                "not lowerable: cross-tag output of an unquantised home"
            );
            ensure!(
                VecReg::lanes(t.width().max(o.ty.width())) == VecReg::lanes(o.ty.width()),
                "not lowerable: narrowing output re-encode"
            );
            let d = self.alloc(usize::MAX, None)?;
            let (ss, ds) = (must_packed(t)?, must_packed(o.ty)?);
            self.push_ins(&format!("VCVT{ss}2{ds}"), d, vec![Operand::Vreg(r)], None, false);
            Ok(d)
        } else if let Node::Const(p) = g.node(o.node) {
            // Bits demand: the journal load *encodes* at `o.ty`, which
            // matches the direct path's output encode by construction —
            // no round-trip guard needed.
            let d = self.alloc(usize::MAX, None)?;
            let plane = **p;
            self.load_const(d, o.ty, &plane, false)?;
            Ok(d)
        } else {
            bail!("internal lowering error: output node was never materialized")
        }
    }
}

// ---------------------------------------------------------------------------
// Mnemonic spelling
// ---------------------------------------------------------------------------

use crate::num::F32;

fn is_raw(n: &Node) -> bool {
    matches!(
        n,
        Node::Bin { .. }
            | Node::RndScale { .. }
            | Node::Fma { .. }
            | Node::Dot { .. }
            | Node::Broadcast { .. }
    )
}

fn is_zero_const(g: &Graph, id: NodeId) -> bool {
    matches!(g.node(id), Node::Const(p) if p.iter().all(|v| v.to_bits() == 0))
}

fn mask_bits(lanes: usize) -> u64 {
    if lanes >= 64 {
        u64::MAX
    } else {
        (1u64 << lanes) - 1
    }
}

/// Packed-lane mnemonic suffix for `t` (`None`: no packed spelling).
fn packed_suffix(t: LaneType) -> Option<String> {
    use crate::num::{NanStyle, BF16, E4M3, E5M2, F16, F64};
    match t {
        LaneType::Takum(n) => Some(format!("PT{n}")),
        LaneType::Mini(s) if s.name == F16.name => Some("PH".into()),
        LaneType::Mini(s) if s.name == F32.name => Some("PS".into()),
        LaneType::Mini(s) if s.name == F64.name => Some("PD".into()),
        LaneType::Mini(s) if s.name == BF16.name => Some("PBF16".into()),
        LaneType::Mini(s) if s.name == E4M3.name => Some("HF8".into()),
        LaneType::Mini(s) if s.name == E5M2.name => Some("BF8".into()),
        LaneType::MiniSat(s) if s.name == E4M3.name && s.nan == NanStyle::Fn => {
            Some("HF8S".into())
        }
        LaneType::MiniSat(s) if s.name == E5M2.name => Some("BF8S".into()),
        _ => None,
    }
}

/// Scalar mnemonic suffix for `t` (`None`: the ISA has no scalar form —
/// bf16 and the OFP8 formats are packed-only).
fn scalar_suffix(t: LaneType) -> Option<String> {
    use crate::num::{F16, F64};
    match t {
        LaneType::Takum(n) => Some(format!("ST{n}")),
        LaneType::Mini(s) if s.name == F16.name => Some("SH".into()),
        LaneType::Mini(s) if s.name == F32.name => Some("SS".into()),
        LaneType::Mini(s) if s.name == F64.name => Some("SD".into()),
        _ => None,
    }
}

fn must_packed(t: LaneType) -> Result<String> {
    packed_suffix(t).ok_or_else(|| anyhow!("not lowerable: no packed mnemonic for {t:?}"))
}

fn must_suffix(t: LaneType, scalar: bool) -> Result<String> {
    let s = if scalar { scalar_suffix(t) } else { packed_suffix(t) };
    s.ok_or_else(|| {
        anyhow!("not lowerable: no {} mnemonic for {t:?}", if scalar { "scalar" } else { "packed" })
    })
}

fn bin_name(op: BinOp) -> &'static str {
    match op {
        BinOp::Add => "ADD",
        BinOp::Sub => "SUB",
        BinOp::Mul => "MUL",
        BinOp::Div => "DIV",
        BinOp::Min => "MIN",
        BinOp::Max => "MAX",
        BinOp::Scalef => "SCALEF",
    }
}

fn fma_name(k: FmaKind) -> &'static str {
    match k {
        FmaKind::Madd => "MADD",
        FmaKind::Msub => "MSUB",
        FmaKind::Nmadd => "NMADD",
        FmaKind::Nmsub => "NMSUB",
    }
}

fn order_name(o: FmaOrder) -> &'static str {
    match o {
        FmaOrder::O132 => "132",
        FmaOrder::O213 => "213",
        FmaOrder::O231 => "231",
    }
}
