//! # Graph compiler: rewrite-rule optimizer + graph→program lowering
//!
//! The HLO-lite dataflow graph ([`crate::sim::graph`]) started as an
//! interpreter with two ad-hoc cleanup passes. This module grows it into
//! a small compiler: a declarative **rewrite-rule table** ([`rules`]), a
//! **fixpoint pass driver** with per-rule accounting and a budget fuse
//! ([`driver`]), and a **lowering pass** back to [`crate::sim::Program`]
//! ([`lower`]) so an optimized graph runs on the vector backend through
//! the SIMD tier cascade instead of the interpreter.
//!
//! This is the paper's headline made measurable: convert chains and the
//! OFP8 storage↔compute tax are exactly what a rewrite engine erases,
//! while takum cells — one format end to end — enter the optimizer
//! already near the fixpoint. The `graph-opt` column of
//! `benches/kernels.rs` quantifies the difference.
//!
//! ## The rule table
//!
//! | rule            | tier        | rewrite                                                |
//! |-----------------|-------------|--------------------------------------------------------|
//! | `convert-fold`  | exact       | `Convert_T(x)` → `x` when `x` is already quantised at `T` (or a constant whose lanes round-trip at `T` bit-exactly) |
//! | `convert-widen` | exact       | `Convert_W(x@T)` → `x` when `T` embeds losslessly in `W` (takum prefix nesting, minifloat spec inclusion) |
//! | `mul-one`       | exact       | `x * 1` → `x` (per-lane: the constant plane is all-ones) |
//! | `add-zero`      | exact       | `x + (-0.0)` → `x`, `x - (+0.0)` → `x`                  |
//! | `mul-zero`      | exact       | `x * 0` → `Const` (lane-wise product — signs/NaNs kept) |
//! | `dead-select`   | exact       | `Select(mask,a,b)` → `a` when mask is all-set, `b` when all-clear |
//! | `select-same`   | exact       | `Select(_, a, a)` → `a`                                 |
//! | `fma-fuse`      | contractive | `Add(Mul(a,b), c)` → `Fma(a,b,c)`                       |
//! | `dot-widen`     | contractive | `Convert_{2w}(…mul/add…)` dot shapes → widening `Dot`   |
//! | `cse`           | exact       | structural hash-consing (driver-integrated)             |
//!
//! ## Soundness contract
//!
//! Every **exact**-tier rule preserves planes *bit-identically*; the
//! common foundation is **quantisation idempotence**: a plane already
//! produced by `decode_T ∘ encode_T` is a fixpoint of it, so a second
//! quantisation at `T` — explicit (`convert-fold`) or via a lossless
//! embedding (`convert-widen`) — is the identity. The algebraic rules
//! fire only under **finite-lane proofs**: the rule inspects the actual
//! constant plane (all lanes `1.0`, all lanes `-0.0`, …), never an
//! algebraic abstraction, so IEEE corner cases (signed zeros, NaN
//! payloads, `-0.0 + 0.0`) are decided on the real bits. Each rule's
//! doc comment in [`rules`] states its individual proof obligation.
//! **Contractive** rules (`fma-fuse`, `dot-widen`) reduce rounding error
//! and are mathematically tighter but not bit-identical — they live
//! behind [`RuleSet::all`] and are *excluded* from the engine's
//! optimize-then-lower path, which uses [`RuleSet::exact`] so the
//! bit-identity pin holds.
//!
//! ## Fixpoint and the budget fuse
//!
//! The driver iterates alias-table walks until an iteration applies no
//! rewrite. Built-in rules strictly descend (alias to an existing node,
//! or replace with a cheaper body), so the fixpoint is reached in
//! finitely many iterations; the budget ([`RULE_BUDGET_DEFAULT`]) is a
//! fuse against a future mis-written rule pair, tripping only at an
//! iteration boundary so the graph stays consistent.
//! [`OptReport`] carries per-rule counts, node shrinkage, iterations and
//! the fuse state — [`OptReport::pass_stats`] is the
//! [`crate::sim::PassStats`] view the engine threads into telemetry.
//!
//! ## Lowering invariants
//!
//! [`lower`] re-emits an optimized graph as an executable instruction
//! stream (interned mnemonics, the same spellings the assembler and
//! `LanePlan::resolve` speak). Its four invariants — the home invariant,
//! operand exactness, initial-state mask reconstruction, scratch
//! restoration — are documented in [`lower`]'s module docs; together
//! they pin **lift → optimize → lower → run bit-identical to direct
//! execution**, which `rust/tests/differential_fuzz.rs` asserts for
//! every liftable corpus seed across every `Backend × CodecMode`
//! config. Every lowered program passes the static verifier under
//! `Verify::Deny` with the [`Lowered::externals`] journal. Graphs
//! outside the invariants (mask states the initial `k` registers cannot
//! reproduce, unquantised cross-type uses, register pressure) are *not
//! lowerable*: the engine falls back to direct execution — lowering is
//! an optimization, never an obligation.
//!
//! ## Adding a rewrite rule
//!
//! 1. Write the matcher in [`rules`] as a `fn(&Graph, NodeId) ->
//!    Option<Rewrite>` — return [`Rewrite::Alias`] to redirect uses to
//!    an existing node or [`Rewrite::Replace`] to swap the node body.
//!    Never allocate new nodes; that keeps termination a descent
//!    argument.
//! 2. State the soundness proof in the rule's doc comment: why the
//!    rewritten plane is bit-identical (exact tier) or tighter
//!    (contractive tier), citing the idempotence/finite-lane facts it
//!    relies on.
//! 3. Append a `Rule { name, exact, apply }` entry to the table —
//!    order matters (first match wins a node per iteration), so put
//!    cheaper/more-general rules first. Exact rules must keep
//!    `exact: true` only if step 2's proof is bit-level.
//! 4. Pin it in `rust/tests/opt.rs` with a positive graph (rule fires,
//!    plane unchanged) and a negative graph (near-miss must not fire),
//!    and rely on the differential-fuzz bit-identity axis as the
//!    backstop.

pub mod rules;
pub mod driver;
pub mod lower;

pub use driver::{OptReport, Optimizer, RULE_BUDGET_DEFAULT};
pub use lower::{lower, run_lowered, Lowered};
pub use rules::{Rewrite, Rule, RuleSet, CSE_RULE};
