//! The fixpoint pass driver: apply the rule table node by node until
//! nothing fires, with structural CSE folded into every iteration and a
//! rule-budget fuse against non-terminating rule sets.
//!
//! ## Iteration model
//!
//! One iteration walks the graph in topological (construction) order
//! keeping an **alias table**. For each node it first resolves the
//! node's operands through the table (so chains collapse within a
//! single pass — the same idiom as the legacy convert-pair fold), then
//! offers the node to the rules in table order; the first rule that
//! fires wins the node for this iteration. Nodes that survive unaliased
//! are structurally hashed for CSE: a node identical (operator,
//! operands, immediates, bit-exact constant planes) to an earlier
//! survivor is aliased to it. After the walk, outputs/returns are
//! remapped and dead nodes eliminated. Iterations repeat until one
//! applies no rewrite.
//!
//! ## Termination and the budget fuse
//!
//! Every built-in rewrite either redirects uses to an *existing* node
//! (strictly reducing live-node count after elimination) or replaces a
//! node with a cheaper body (`Fma` for `Add`+`Mul`, a constant for a
//! multiply) — a lexicographic descent that reaches a fixpoint in
//! finitely many iterations. The budget ([`RULE_BUDGET_DEFAULT`] total
//! applications, configurable) is a fuse, not a scheduler: it exists so
//! a future mis-written rule pair that ping-pongs cannot hang the
//! engine. The fuse trips at an iteration boundary, so the graph is
//! always left consistent; [`OptReport::budget_exhausted`] records the
//! trip.

use std::collections::HashMap;

use crate::sim::graph::{BinOp, Graph, Node, NodeId, PassStats, ReduceOp};
use crate::sim::lanes::{FmaKind, FmaOrder, LaneType};

use super::rules::{Rewrite, RuleSet, CSE_RULE};

/// Default total-application budget (fuse, not scheduler — see module
/// docs).
pub const RULE_BUDGET_DEFAULT: usize = 10_000;

/// Per-run report: what fired, how often, and what it bought.
#[derive(Debug, Clone, Default)]
pub struct OptReport {
    /// `(rule name, applications)` in rule-table order, CSE last. Rules
    /// that never fired still appear with a zero count, so reports are
    /// shape-stable across cells.
    pub per_rule: Vec<(&'static str, usize)>,
    pub nodes_before: usize,
    pub nodes_after: usize,
    pub iterations: usize,
    /// The budget fuse tripped before the fixpoint was reached.
    pub budget_exhausted: bool,
}

impl OptReport {
    /// Applications of one named rule (0 when absent).
    pub fn rule(&self, name: &str) -> usize {
        self.per_rule.iter().find(|(n, _)| *n == name).map_or(0, |(_, c)| *c)
    }

    /// Total rule applications (CSE included).
    pub fn total_applied(&self) -> usize {
        self.per_rule.iter().map(|(_, c)| c).sum()
    }

    /// Nodes removed end to end.
    pub fn nodes_removed(&self) -> usize {
        self.nodes_before.saturating_sub(self.nodes_after)
    }

    /// The [`PassStats`] view of this report (what the engine and tests
    /// thread around): convert-rule applications under `converts_folded`,
    /// node shrinkage under `dead_removed`, the full table in `per_rule`.
    pub fn pass_stats(&self) -> PassStats {
        PassStats {
            converts_folded: self.rule("convert-fold") + self.rule("convert-widen"),
            dead_removed: self.nodes_removed(),
            per_rule: self.per_rule.clone(),
        }
    }

    /// Human-readable per-rule table (the `opt` subcommand's report).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "nodes {} -> {} ({} removed), {} iteration(s){}\n",
            self.nodes_before,
            self.nodes_after,
            self.nodes_removed(),
            self.iterations,
            if self.budget_exhausted { ", BUDGET EXHAUSTED" } else { "" },
        ));
        for (name, count) in &self.per_rule {
            out.push_str(&format!("  {name:<14} {count}\n"));
        }
        out
    }
}

/// The rewrite driver: a rule set plus a budget.
pub struct Optimizer {
    rules: RuleSet,
    budget: usize,
}

impl Optimizer {
    /// Bit-identity-preserving rules only — what the engine's
    /// optimize-then-lower path runs.
    pub fn exact() -> Optimizer {
        Optimizer { rules: RuleSet::exact(), budget: RULE_BUDGET_DEFAULT }
    }

    /// Exact + contractive rules — interpreter-only workloads that want
    /// the rounding-reducing fusions too.
    pub fn all() -> Optimizer {
        Optimizer { rules: RuleSet::all(), budget: RULE_BUDGET_DEFAULT }
    }

    /// Override the application budget (tests drive this down to prove
    /// the fuse trips cleanly).
    pub fn with_budget(mut self, budget: usize) -> Optimizer {
        self.budget = budget;
        self
    }

    /// Run to fixpoint (or budget) on `g`.
    pub fn run(&self, g: &mut Graph) -> OptReport {
        let mut report = OptReport {
            per_rule: self
                .rules
                .rules()
                .iter()
                .map(|r| (r.name, 0))
                .chain([(CSE_RULE, 0)])
                .collect(),
            nodes_before: g.len(),
            ..OptReport::default()
        };
        loop {
            if report.total_applied() >= self.budget {
                report.budget_exhausted = true;
                break;
            }
            report.iterations += 1;
            let applied = self.iterate(g, &mut report.per_rule);
            g.eliminate_dead();
            if applied == 0 {
                break;
            }
        }
        report.nodes_after = g.len();
        report
    }

    /// One alias-table walk; returns the number of rewrites applied.
    fn iterate(&self, g: &mut Graph, per_rule: &mut [(&'static str, usize)]) -> usize {
        let n = g.len();
        let mut alias: Vec<NodeId> = (0..n).map(NodeId::new).collect();
        let mut seen: HashMap<Key, NodeId> = HashMap::new();
        let mut applied = 0usize;
        for i in 0..n {
            // Resolve operands through the aliases established so far
            // (operands always precede their users).
            for op in g.nodes_mut()[i].operands_mut().into_iter().flatten() {
                *op = alias[op.idx()];
            }
            let id = NodeId::new(i);
            let mut aliased = false;
            for (r, rule) in self.rules.rules().iter().enumerate() {
                match (rule.apply)(g, id) {
                    Some(Rewrite::Alias(target)) => {
                        alias[i] = alias[target.idx()];
                        per_rule[r].1 += 1;
                        applied += 1;
                        aliased = true;
                    }
                    Some(Rewrite::Replace(node)) => {
                        g.nodes_mut()[i] = node;
                        per_rule[r].1 += 1;
                        applied += 1;
                    }
                    None => continue,
                }
                break; // first matching rule wins this node
            }
            if !aliased {
                // Structural CSE over the surviving (possibly replaced)
                // body. Identical structure evaluates to identical
                // planes — the evaluators are deterministic — so this
                // is exact.
                match seen.entry(Key::of(g.node(id))) {
                    std::collections::hash_map::Entry::Occupied(e) => {
                        if *e.get() != id {
                            alias[i] = *e.get();
                            per_rule.last_mut().expect("cse slot").1 += 1;
                            applied += 1;
                        }
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(id);
                    }
                }
            }
        }
        for o in g.outputs_mut() {
            o.node = alias[o.node.idx()];
        }
        for r in g.returns_mut() {
            *r = alias[r.idx()];
        }
        applied
    }
}

// ---------------------------------------------------------------------------
// Structural hashing for CSE
// ---------------------------------------------------------------------------

/// Lane types keyed structurally (discriminant, width, spec name) —
/// [`LaneType`] itself carries a [`crate::num::MinifloatSpec`] that does
/// not implement `Hash`.
type TyKey = (u8, u32, &'static str);

fn ty_key(t: LaneType) -> TyKey {
    match t {
        LaneType::Takum(n) => (0, n, ""),
        LaneType::Mini(s) => (1, s.bits(), s.name),
        LaneType::MiniSat(s) => (2, s.bits(), s.name),
        LaneType::UInt(w) => (3, w, ""),
        LaneType::SInt(w) => (4, w, ""),
    }
}

/// Structural identity of a node: operator, operands, immediates, and
/// bit patterns of constant planes (bit-exact — two NaN payloads only
/// merge when identical).
#[derive(PartialEq, Eq, Hash)]
enum Key {
    Const(Vec<u64>),
    Param(usize),
    Load(u8, TyKey),
    Convert(u32, TyKey),
    Bin(u8, u32, u32),
    RndScale(u32, i32),
    Fma(u8, u8, u32, u32, u32),
    Dot(u32, u32, u32),
    Reduce(u8, u32, usize),
    Select(u64, u32, u32),
    Broadcast(u32),
}

impl Key {
    fn of(n: &Node) -> Key {
        let ix = |id: NodeId| id.idx() as u32;
        match n {
            Node::Const(p) => Key::Const(p.iter().map(|x| x.to_bits()).collect()),
            Node::Param(k) => Key::Param(*k),
            Node::Load { reg, ty } => Key::Load(*reg, ty_key(*ty)),
            Node::Convert { src, ty } => Key::Convert(ix(*src), ty_key(*ty)),
            Node::Bin { op, a, b } => Key::Bin(bin_key(*op), ix(*a), ix(*b)),
            Node::RndScale { src, m } => Key::RndScale(ix(*src), *m),
            Node::Fma { kind, order, a, b, z } => {
                Key::Fma(fma_key(*kind), order_key(*order), ix(*a), ix(*b), ix(*z))
            }
            Node::Dot { a, b, z } => Key::Dot(ix(*a), ix(*b), ix(*z)),
            Node::Reduce { op, src, lanes } => Key::Reduce(reduce_key(*op), ix(*src), *lanes),
            Node::Select { mask, a, b } => Key::Select(*mask, ix(*a), ix(*b)),
            Node::Broadcast { src } => Key::Broadcast(ix(*src)),
        }
    }
}

fn bin_key(op: BinOp) -> u8 {
    match op {
        BinOp::Add => 0,
        BinOp::Sub => 1,
        BinOp::Mul => 2,
        BinOp::Div => 3,
        BinOp::Min => 4,
        BinOp::Max => 5,
        BinOp::Scalef => 6,
    }
}

fn fma_key(k: FmaKind) -> u8 {
    match k {
        FmaKind::Madd => 0,
        FmaKind::Msub => 1,
        FmaKind::Nmadd => 2,
        FmaKind::Nmsub => 3,
    }
}

fn order_key(o: FmaOrder) -> u8 {
    match o {
        FmaOrder::O132 => 0,
        FmaOrder::O213 => 1,
        FmaOrder::O231 => 2,
    }
}

fn reduce_key(op: ReduceOp) -> u8 {
    match op {
        ReduceOp::Sum => 0,
        ReduceOp::Max => 1,
    }
}
