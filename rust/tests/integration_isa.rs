//! Integration over the ISA model: database ↔ transform ↔ proposed set ↔
//! simulator. Checks the E5–E10 invariants end to end.

use takum_avx10::isa::database::{self, Category};
use takum_avx10::isa::pattern::Pattern;
use takum_avx10::isa::proposed::{evaluate, table_rows};
use takum_avx10::isa::transform::{map_instruction, Mapping};
use takum_avx10::sim::{LaneType, Machine, Instruction, Operand};

#[test]
fn e10_headline_counts() {
    // The paper's §IV split; integer carries the documented +13 delta.
    assert_eq!(database::category_count(Category::Bitwise), 220);
    assert_eq!(database::category_count(Category::Mask), 59);
    assert_eq!(database::category_count(Category::Integer), 120);
    assert_eq!(database::category_count(Category::FloatingPoint), 363);
    assert_eq!(database::category_count(Category::Cryptographic), 7);
}

#[test]
fn every_avx_instruction_matches_its_own_group_pattern() {
    for g in database::groups() {
        let pats: Vec<Pattern> = g
            .spec
            .avx_patterns
            .iter()
            .map(|p| Pattern::parse(p).unwrap())
            .collect();
        for m in &g.avx_instructions {
            assert!(
                pats.iter().any(|p| p.matches(m)),
                "{m} not matched by {} patterns",
                g.spec.id
            );
        }
    }
}

#[test]
fn proposed_takum_arithmetic_is_executable() {
    // The generalisation is not just names: the proposed packed/scalar
    // takum mnemonics of the unified F01-06 group actually run on the
    // simulator. Coverage: all binary/unary arithmetic, the full
    // 12-member FMA family, the immediate-operand ops, and VCLASS/VCMP.
    let rows = table_rows();
    let fp = rows.iter().find(|r| r.merged_id == "F01-06").unwrap();
    let all: Vec<String> = fp
        .proposed_patterns
        .iter()
        .flat_map(|p| Pattern::parse(p).unwrap().expand())
        .collect();
    assert_eq!(all.len(), 46 * 8);

    let mut mach = Machine::new();
    let mut ran = 0;
    let mut skipped = 0;
    for m in &all {
        // Work out the lane type from the trailing suffix.
        let Some(pos) = m.find("PT").or(m.find("ST")) else { continue };
        let Some((ty, _)) = LaneType::parse_fp(&m[pos..]) else { continue };
        if !matches!(ty, LaneType::Takum(_)) {
            continue;
        }
        mach.load_f64(0, ty, &[4.0, 1.0]);
        mach.load_f64(1, ty, &[2.0, 1.0]);
        mach.load_f64(2, ty, &[1.0, 1.0]);
        // CLASS writes a mask; everything else a vector. Immediate ops
        // get a trailing imm (harmless for the others? no — only pass
        // imm to the ops that parse it).
        let ins = if m.starts_with("VCLASS") {
            Instruction::new(m, Operand::Kreg(1), vec![Operand::Vreg(0), Operand::Imm(7)])
        } else if m.starts_with("VCMP") {
            Instruction::new(
                m,
                Operand::Kreg(1),
                vec![Operand::Vreg(0), Operand::Vreg(1), Operand::Imm(1)],
            )
        } else if m.starts_with("VMINMAX") || m.starts_with("VRNDSCALE")
            || m.starts_with("VREDUCE")
        {
            Instruction::new(
                m,
                Operand::Vreg(2),
                vec![Operand::Vreg(0), Operand::Vreg(1), Operand::Imm(0)],
            )
        } else {
            Instruction::new(m, Operand::Vreg(2), vec![Operand::Vreg(0), Operand::Vreg(1)])
        };
        match mach.step(&ins) {
            Ok(()) => ran += 1,
            Err(e) => {
                let msg = e.to_string();
                assert!(
                    msg.contains("unimplemented"),
                    "{m}: unexpected failure {msg}"
                );
                skipped += 1;
            }
        }
    }
    // Executable today: 15 value ops + 12 FMA + CLASS + CMP = 29 of the
    // 46 families, × {P,S} × 4 widths; the rest (FIXUPIMM, RANGE, complex
    // FC?MADD/MULC, COMI/COMX, UCMP) are counted as skipped.
    assert_eq!(ran + skipped, 46 * 8);
    assert!(ran >= 29 * 8, "executable coverage regressed: ran={ran}");
}

#[test]
fn conversion_matrix_is_closed_and_executable() {
    // Every proposed packed int↔takum conversion executes.
    let rows = table_rows();
    let f7 = rows.iter().find(|r| r.merged_id == "F07").unwrap();
    let mut mach = Machine::new();
    mach.load_f64(0, LaneType::Takum(16), &[1.0, 2.0]);
    mach.load_f64(1, LaneType::SInt(32), &[3.0, 4.0]);
    let mut ran = 0;
    for m in f7
        .proposed_patterns
        .iter()
        .flat_map(|p| Pattern::parse(p).unwrap().expand())
    {
        if !m.starts_with("VCVTP") && !m.contains("2P") {
            continue; // scalar forms share the packed path; exercise packed
        }
        if m.starts_with("VCVTS") || m.contains("2S") {
            continue;
        }
        let src = if m.contains("PT") && m.find("PT") == Some(4) { 0u8 } else { 1u8 };
        mach.step(&Instruction::new(&m, Operand::Vreg(5), vec![Operand::Vreg(src)]))
            .unwrap_or_else(|e| panic!("{m}: {e}"));
        ran += 1;
    }
    // packed directions: PS/PU×4 → PT×4 and PT×4 → PS/PU×4 = 64.
    assert_eq!(ran, 64);
}

#[test]
fn rename_is_deterministic_and_total() {
    // map_instruction is total over the database and stable.
    for g in database::groups() {
        for m in &g.avx_instructions {
            let a = map_instruction(m, g.spec.id);
            let b = map_instruction(m, g.spec.id);
            assert_eq!(a, b, "{m}");
            if let Mapping::To(t) = a {
                assert!(t.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit()), "{t}");
            }
        }
    }
}

#[test]
fn evaluation_is_consistent_with_rows() {
    let e = evaluate();
    let rows = table_rows();
    let avx_total: usize = rows.iter().map(|r| r.avx_count).sum();
    let prop_total: usize = rows.iter().map(|r| r.proposed_count).sum();
    let eval_avx: usize = e.per_category.iter().map(|(_, _, ours, _)| ours).sum();
    let eval_prop: usize = e.per_category.iter().map(|(_, _, _, p)| p).sum();
    assert_eq!(avx_total, eval_avx);
    assert_eq!(prop_total, eval_prop);
}
