//! Integration over the kernel-builder subsystem: cross-ISA equivalence
//! of the suite, golden instruction counts, codec-mode bit-identity, and
//! determinism of the parallel kernel sweep.

use takum_avx10::coordinator::{kernel_sweep, KernelSweep};
use takum_avx10::engine::{Engine, EngineConfig};
use takum_avx10::kernels::{run_suite, Isa, Kernel, KernelSpec, Pipeline};
use takum_avx10::sim::{Backend, CodecMode};

/// Env-default engine (the execution front door).
fn engine() -> Engine {
    EngineConfig::from_env().build().unwrap()
}

/// Engine with both execution axes pinned.
fn engine_cfg(mode: CodecMode, backend: Backend) -> Engine {
    EngineConfig::new().codec(mode).backend(backend).build().unwrap()
}

/// Both ISAs produce finite, comparable relative errors on shared inputs
/// for every kernel. The bounds are deliberately loose sanity gates
/// (order of magnitude, not accuracy targets): 16-bit formats compute
/// with ≥8 significand bits and land far below them; the 8-bit softmax
/// runs its whole range-reduced exp in takum8 arithmetic, which is
/// exactly the "8-bit general-purpose arithmetic" stress the paper
/// claims takum survives — coarse, but finite and normalised.
#[test]
fn cross_isa_equivalence_finite_and_comparable() {
    let results = run_suite(&engine(), 128, 0xE0_11).unwrap();
    assert_eq!(results.len(), 36); // 6 kernels × 6 formats
    for r in &results {
        assert!(
            r.rel_error.is_finite() && r.rel_error >= 0.0,
            "{}/{}: rel_error={}",
            r.kernel,
            r.format,
            r.rel_error
        );
        let bound = match (r.format.as_str(), r.kernel.as_str()) {
            // 16-bit storage (and the OFP8 pipelines, which compute in PH).
            ("t16" | "bf16" | "f16", _) => 0.5,
            (_, "softmax") => 6.0,
            _ => 1.5,
        };
        assert!(r.rel_error < bound, "{}/{}: rel_error={}", r.kernel, r.format, r.rel_error);
    }
    // Every kernel ran on both ISAs.
    for k in Kernel::ALL {
        let of_kernel: Vec<_> = results.iter().filter(|r| r.kernel == k.name()).collect();
        assert!(of_kernel.iter().any(|r| r.isa == Isa::Proposed), "{}", k.name());
        assert!(of_kernel.iter().any(|r| r.isa == Isa::Baseline), "{}", k.name());
    }
    // The wider takum is strictly more accurate on the dot product (a
    // ~100× expected gap; the assertion has orders of magnitude of
    // slack).
    let err = |kernel: &str, format: &str| {
        results
            .iter()
            .find(|r| r.kernel == kernel && r.format == format)
            .unwrap()
            .rel_error
    };
    assert!(err("dot", "t16") < err("dot", "t8"));
}

/// Golden instruction-count shape per kernel/format: the OFP8 pipelines
/// must pay nonzero storage↔compute conversions, the takum (and native
/// bf16/fp16) pipelines none — on every kernel of the suite.
#[test]
fn golden_convert_counts_ofp8_pays_takum_does_not() {
    let results = run_suite(&engine(), 64, 3).unwrap();
    for r in &results {
        match r.format.as_str() {
            "e4m3" | "e5m2" => assert!(
                r.convert_instructions > 0,
                "{}/{} should pay the OFP8 convert tax",
                r.kernel,
                r.format
            ),
            _ => assert_eq!(
                r.convert_instructions, 0,
                "{}/{} must not convert",
                r.kernel, r.format
            ),
        }
        // dp-pipeline kernels actually use the widening dot product.
        if matches!(r.kernel.as_str(), "dot" | "reduce" | "softmax") {
            assert!(r.dp_instructions > 0, "{}/{}", r.kernel, r.format);
        }
        // Proposed-ISA programs never emit a baseline mnemonic and vice
        // versa: the dp mnemonic is format-specific.
        let pipe = Pipeline::for_format(&r.format).unwrap();
        assert_eq!(r.counts.get(pipe.dp).copied().unwrap_or(0), r.dp_instructions);
    }
}

/// Exact golden counts for AXPY at n=128 (1 broadcast-constant setup +
/// one FMA per tile; OFP8 adds 2 promotes + 1 demote per tile and 1
/// promote for the constant). Derived from the lowering, independent of
/// data.
#[test]
fn golden_axpy_instruction_counts() {
    let eng = engine();
    for (fmt, executed, converts) in [("t8", 3u64, 0u64), ("bf16", 5, 0), ("e4m3", 18, 13)] {
        let spec = KernelSpec { kernel: Kernel::Axpy, format: fmt, n: 128, seed: 1 };
        let r = spec.run(&eng).unwrap();
        assert_eq!(r.executed, executed, "{fmt} executed");
        assert_eq!(r.convert_instructions, converts, "{fmt} converts");
    }
}

/// `CodecMode::Arith` vs the default LUT engine, routed through the
/// heaviest kernel (softmax: converts, FMA chains, both reduction trees,
/// `VRNDSCALE`/`VSCALEF`): bit-identical error and identical instruction
/// streams.
#[test]
fn softmax_arith_vs_lut_bit_identity() {
    let lut = EngineConfig::from_env().codec(CodecMode::Lut).build().unwrap();
    let arith = EngineConfig::from_env().codec(CodecMode::Arith).build().unwrap();
    for fmt in ["t8", "t16", "bf16", "e4m3"] {
        let spec = KernelSpec { kernel: Kernel::Softmax, format: fmt, n: 64, seed: 7 };
        let fast = spec.run(&lut).unwrap();
        let slow = spec.run(&arith).unwrap();
        assert_eq!(
            fast.rel_error.to_bits(),
            slow.rel_error.to_bits(),
            "{fmt}: lut={} arith={}",
            fast.rel_error,
            slow.rel_error
        );
        assert_eq!(fast.executed, slow.executed, "{fmt}");
        assert_eq!(fast.counts, slow.counts, "{fmt}");
    }
}

/// The parallel kernel sweep is a pure function of its config: identical
/// results for 1, 2 and 5 workers, matching the sequential suite.
#[test]
fn kernel_sweep_deterministic_and_matches_suite() {
    let spec = KernelSweep {
        kernels: Kernel::ALL.to_vec(),
        formats: vec!["t8", "t16", "bf16", "e4m3"],
        sizes: vec![64, 128],
        seed: Some(0xD15C),
    };
    let eng = |workers: usize| EngineConfig::from_env().workers(workers).build().unwrap();
    let (base, metrics) = kernel_sweep(&eng(1), &spec).unwrap();
    assert_eq!(base.len(), 6 * 4 * 2);
    assert_eq!(metrics.per_worker.iter().sum::<usize>(), base.len());
    for workers in [2usize, 5] {
        let (par, m) = kernel_sweep(&eng(workers), &spec).unwrap();
        assert_eq!(par.len(), base.len());
        for (a, b) in par.iter().zip(&base) {
            assert_eq!((&a.kernel, &a.format, a.n), (&b.kernel, &b.format, b.n));
            assert_eq!(
                a.rel_error.to_bits(),
                b.rel_error.to_bits(),
                "{}/{} n={} workers={workers}",
                a.kernel,
                a.format,
                a.n
            );
            assert_eq!(a.executed, b.executed);
            assert_eq!(a.counts, b.counts);
        }
        assert_eq!(m.per_worker.iter().sum::<usize>(), base.len());
    }
}

/// The plane-backend acceptance pin: the whole suite — every kernel ×
/// every format, both ISAs — must be **byte-identical** across
/// `Backend::Scalar`, `Backend::Vector` and `Backend::Graph` at
/// n ∈ {64, 128}: same `rel_error` bit patterns, same
/// executed/dp/convert counts, same per-mnemonic histograms. In
/// combination with `CodecMode::Arith` (pinned against the LUT engine by
/// the earlier tests), this closes the square
/// Graph ≡ Vector ≡ Scalar ≡ Arith.
#[test]
fn suite_byte_identical_across_backends() {
    for n in [64usize, 128] {
        let scalar =
            run_suite(&engine_cfg(CodecMode::default(), Backend::Scalar), n, 0xBAC0).unwrap();
        for backend in [Backend::Vector, Backend::Graph] {
            let other =
                run_suite(&engine_cfg(CodecMode::default(), backend), n, 0xBAC0).unwrap();
            assert_eq!(scalar.len(), other.len());
            for (s, v) in scalar.iter().zip(&other) {
                assert_eq!((&s.kernel, &s.format, s.n), (&v.kernel, &v.format, v.n));
                assert_eq!(
                    s.rel_error.to_bits(),
                    v.rel_error.to_bits(),
                    "{}/{} n={n} {backend:?}: rel_error {} vs {}",
                    s.kernel,
                    s.format,
                    s.rel_error,
                    v.rel_error
                );
                assert_eq!(s.executed, v.executed, "{}/{} n={n} {backend:?}", s.kernel, s.format);
                assert_eq!(
                    s.dp_instructions, v.dp_instructions,
                    "{}/{} n={n} {backend:?}",
                    s.kernel, s.format
                );
                assert_eq!(
                    s.convert_instructions, v.convert_instructions,
                    "{}/{} n={n} {backend:?}",
                    s.kernel, s.format
                );
                assert_eq!(s.counts, v.counts, "{}/{} n={n} {backend:?}", s.kernel, s.format);
            }
        }
    }
    // GEMM through the same gate (both codec modes on the non-scalar
    // backends).
    use takum_avx10::harness::gemm::gemm;
    let scalar_eng = engine_cfg(CodecMode::default(), Backend::Scalar);
    for f in ["t8", "t16", "bf16", "e4m3"] {
        for n in [64usize, 128] {
            let s = gemm(&scalar_eng, n, f, 7, 1.0).unwrap();
            for backend in [Backend::Vector, Backend::Graph] {
                let v = gemm(&engine_cfg(CodecMode::default(), backend), n, f, 7, 1.0).unwrap();
                let a = gemm(&engine_cfg(CodecMode::Arith, backend), n, f, 7, 1.0).unwrap();
                assert_eq!(s.rel_error.to_bits(), v.rel_error.to_bits(), "{f} n={n} {backend:?}");
                assert_eq!(
                    s.rel_error.to_bits(),
                    a.rel_error.to_bits(),
                    "{f} n={n} {backend:?} arith"
                );
                assert_eq!(s.executed, v.executed, "{f} n={n} {backend:?}");
                assert_eq!(s.executed, a.executed, "{f} n={n} {backend:?} arith");
            }
        }
    }
}

/// Softmax with the vector backend forced, against the arithmetic
/// reference — the deep-chain stress (converts, FMA chains, both
/// reduction trees, `VRNDSCALE`/`VSCALEF`) for the chunked plane kernels
/// and the decoded-shadow cache.
#[test]
fn softmax_vector_backend_vs_arith_bit_identity() {
    let vec_lut = engine_cfg(CodecMode::Lut, Backend::Vector);
    let scalar_arith = engine_cfg(CodecMode::Arith, Backend::Scalar);
    for fmt in ["t8", "t16", "bf16", "e4m3"] {
        let spec = KernelSpec { kernel: Kernel::Softmax, format: fmt, n: 64, seed: 7 };
        let fast = spec.run(&vec_lut).unwrap();
        let slow = spec.run(&scalar_arith).unwrap();
        assert_eq!(
            fast.rel_error.to_bits(),
            slow.rel_error.to_bits(),
            "{fmt}: vector-lut={} scalar-arith={}",
            fast.rel_error,
            slow.rel_error
        );
        assert_eq!(fast.executed, slow.executed, "{fmt}");
        assert_eq!(fast.counts, slow.counts, "{fmt}");
    }
}

/// The refactored GEMM emits through the same builder: its instruction
/// mix must stay exactly the dp + convert vocabulary of its pipeline (no
/// stray mnemonics), with the t8-vs-OFP8 count relationships the E11
/// tests already pin.
#[test]
fn gemm_emits_through_the_shared_pipeline_vocabulary() {
    use takum_avx10::harness::gemm::gemm;
    let eng = engine();
    let t8 = gemm(&eng, 32, "t8", 2, 1.0).unwrap();
    assert_eq!(t8.executed, t8.dp_instructions);
    assert_eq!(t8.convert_instructions, 0);
    let e4 = gemm(&eng, 32, "e4m3", 2, 1.0).unwrap();
    assert_eq!(e4.executed, e4.dp_instructions + e4.convert_instructions);
    assert!(e4.convert_instructions == 2 * e4.dp_instructions);
}
