//! Integration: the AOT-compiled Pallas kernels (HLO text artifacts)
//! executed through the PJRT runtime must agree **bit for bit** with the
//! native rust codecs — the L1 ↔ L3 contract of the three-layer design.
//!
//! Requires `make artifacts` (skipped with a loud message otherwise).

use takum_avx10::num::takum_linear;
use takum_avx10::runtime::{PjrtService, TensorF64};
use takum_avx10::util::rng::Rng;
use std::path::Path;

fn service() -> Option<PjrtService> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match PjrtService::start(&dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIPPING runtime integration tests: {e:#} (run `make artifacts`)");
            None
        }
    }
}

const BATCH: usize = 1 << 16;

#[test]
fn artifacts_present() {
    let Some(s) = service() else { return };
    let names = s.handle().names().unwrap();
    for want in ["takum8_roundtrip", "takum16_roundtrip", "takum32_roundtrip", "quant_gemm_t8"] {
        assert!(names.iter().any(|n| n == want), "missing artifact {want} in {names:?}");
    }
}

#[test]
fn pjrt_roundtrip_matches_native_codec_bit_for_bit() {
    let Some(s) = service() else { return };
    let h = s.handle();
    let mut rng = Rng::new(0x7357);
    for n in [8u32, 16, 32] {
        let mut vals: Vec<f64> = (0..BATCH - 16).map(|_| rng.wide_f64(-260, 260)).collect();
        // specials and exact values
        vals.extend_from_slice(&[
            0.0, 1.0, -1.0, 1.5, -0.75, 448.0, 2.0_f64.powi(100), -(2.0_f64.powi(-100)),
            1e300, -1e-300, 3.75, -123.25, f64::MIN_POSITIVE, 2.0, 0.5, -2.0,
        ]);
        assert_eq!(vals.len(), BATCH);
        let out = h
            .run_f64(&format!("takum{n}_roundtrip"), vec![TensorF64::vec(vals.clone())])
            .unwrap();
        let rt = &out[0];
        assert_eq!(rt.len(), BATCH);
        for (i, (&x, &y)) in vals.iter().zip(rt).enumerate() {
            let want = takum_linear::decode(takum_linear::encode(x, n), n);
            assert!(
                y == want || (y.is_nan() && want.is_nan()),
                "n={n} i={i} x={x}: pjrt={y} native={want}"
            );
        }
    }
}

#[test]
fn pjrt_nan_maps_to_nar() {
    let Some(s) = service() else { return };
    let h = s.handle();
    let mut vals = vec![0.0f64; BATCH];
    vals[0] = f64::NAN;
    vals[1] = f64::INFINITY;
    vals[2] = f64::NEG_INFINITY;
    let out = h.run_f64("takum16_roundtrip", vec![TensorF64::vec(vals)]).unwrap();
    assert!(out[0][0].is_nan());
    assert!(out[0][1].is_nan());
    assert!(out[0][2].is_nan());
    assert_eq!(out[0][3], 0.0);
}

#[test]
fn quant_gemm_artifact_runs_and_is_plausible() {
    let Some(s) = service() else { return };
    let h = s.handle();
    let dim = 128usize;
    let mut rng = Rng::new(0xD07);
    let a: Vec<f64> = (0..dim * dim).map(|_| rng.log_normal(0.0, 1.0)).collect();
    let b: Vec<f64> = (0..dim * dim).map(|_| rng.log_normal(0.0, 1.0)).collect();
    let out = h
        .run_f64(
            "quant_gemm_t8",
            vec![
                TensorF64::matrix(a.clone(), dim as i64, dim as i64),
                TensorF64::matrix(b.clone(), dim as i64, dim as i64),
            ],
        )
        .unwrap();
    let c = &out[0];
    assert_eq!(c.len(), dim * dim);
    // f64 reference
    let mut c_ref = vec![0.0f64; dim * dim];
    for i in 0..dim {
        for k in 0..dim {
            let aik = a[i * dim + k];
            for j in 0..dim {
                c_ref[i * dim + j] += aik * b[k * dim + j];
            }
        }
    }
    let (mut num, mut den) = (0.0, 0.0);
    for (x, y) in c.iter().zip(&c_ref) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    let rel = (num / den).sqrt();
    // takum8 inputs, takum16 accumulators: a few percent, not garbage.
    assert!(rel > 1e-4 && rel < 0.2, "rel={rel}");

    // Every output lane must be exactly takum16-representable (the kernel
    // re-quantises its accumulator).
    for (i, &y) in c.iter().enumerate().take(512) {
        let q = takum_linear::decode(takum_linear::encode(y, 16), 16);
        assert_eq!(q, y, "lane {i} not takum16-representable: {y}");
    }
}

#[test]
fn service_is_shareable_across_threads() {
    let Some(s) = service() else { return };
    let h = s.handle();
    std::thread::scope(|scope| {
        for t in 0..4 {
            let h = h.clone();
            scope.spawn(move || {
                let mut vals = vec![1.5f64; BATCH];
                vals[0] = t as f64;
                let out = h.run_f64("takum8_roundtrip", vec![TensorF64::vec(vals)]).unwrap();
                assert_eq!(out[0][1], 1.5);
            });
        }
    });
}
