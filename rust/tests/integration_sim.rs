//! Integration over the simulator: assembled programs, GEMM pipelines and
//! cross-checks against the numeric library.

use takum_avx10::engine::{Engine, EngineConfig};
use takum_avx10::harness::gemm::{gemm, gemm_scaled};
use takum_avx10::num::takum_linear;
use takum_avx10::sim::{assemble, CodecMode, LaneType, Machine};
use takum_avx10::util::rng::Rng;

/// Env-default engine (the front door the old implicit defaults moved
/// behind).
fn engine() -> Engine {
    EngineConfig::from_env().build().unwrap()
}

#[test]
fn assembled_takum_kernel_runs_end_to_end() {
    // A small fused multiply-add chain with masking and compares.
    let prog = assemble(
        "
        ; c = a*b; d = c + a; mask = d > c; e = d (only where mask)
        VMULPT16  v2, v0, v1
        VADDPT16  v3, v2, v0
        VCMPPT16  k1, v3, v2, 6      ; GT
        VADDPT16  v4{k1}{z}, v3, v1
        ",
    )
    .unwrap();
    let mut m = Machine::new();
    let t = LaneType::Takum(16);
    let a = [1.0, -2.0, 0.5, 0.0, 3.0];
    let b = [2.0, 2.0, 2.0, 2.0, 2.0];
    m.load_f64(0, t, &a);
    m.load_f64(1, t, &b);
    m.run(&prog).unwrap();
    let v3 = m.read_f64(3, t);
    let v4 = m.read_f64(4, t);
    for i in 0..5 {
        let c = a[i] * b[i];
        let d = c + a[i];
        assert_eq!(v3[i], d, "lane {i}");
        let expect = if d > c { d + b[i] } else { 0.0 };
        assert_eq!(v4[i], expect, "masked lane {i}");
    }
}

#[test]
fn takum_compare_equals_value_compare_randomised() {
    // The §IV-A claim, checked across thousands of random pairs and all
    // widths: signed-integer comparison of encodings == real comparison.
    let mut rng = Rng::new(0x51);
    for n in [8u32, 16, 32, 64] {
        for _ in 0..2000 {
            let x = rng.wide_f64(-200, 200);
            let y = if rng.chance(0.1) { x } else { rng.wide_f64(-200, 200) };
            let (bx, by) = (takum_linear::encode(x, n), takum_linear::encode(y, n));
            let (kx, ky) = (takum_linear::order_key(bx, n), takum_linear::order_key(by, n));
            let (vx, vy) = (takum_linear::decode(bx, n), takum_linear::decode(by, n));
            assert_eq!(kx < ky, vx < vy, "n={n} x={x} y={y}");
            assert_eq!(kx == ky, vx == vy, "n={n} x={x} y={y}");
        }
    }
}

#[test]
fn gemm_instruction_count_advantage_scales() {
    // The takum pipeline's instruction-count advantage over the OFP8
    // convert-then-compute pipeline grows linearly with the problem.
    let eng = engine();
    for n in [16usize, 32, 64] {
        let t8 = gemm(&eng, n, "t8", 5, 1.0).unwrap();
        let e4 = gemm(&eng, n, "e4m3", 5, 1.0).unwrap();
        // t8 processes 64 narrow lanes/dp vs 32, and needs no converts:
        // ≥ 3× fewer instructions.
        assert!(
            e4.executed as f64 / t8.executed as f64 >= 3.0,
            "n={n}: t8={} e4m3={}",
            t8.executed,
            e4.executed
        );
    }
}

#[test]
fn simulator_quantisation_matches_library_roundtrip() {
    // Values stored to takum lanes and read back must equal the library's
    // round-trip (the simulator *is* the library numerically).
    let mut rng = Rng::new(0x52);
    let mut m = Machine::new();
    for n in [8u32, 16, 32] {
        let t = LaneType::Takum(n);
        let lanes = (512 / n) as usize;
        let vals: Vec<f64> = (0..lanes).map(|_| rng.wide_f64(-100, 100)).collect();
        m.load_f64(7, t, &vals);
        let back = m.read_f64(7, t);
        let f = takum_avx10::num::format_by_name(&format!("takum{n}")).unwrap();
        for (i, (&x, &y)) in vals.iter().zip(&back).enumerate() {
            assert_eq!(y, f.roundtrip(x), "n={n} lane={i}");
        }
    }
}

#[test]
fn lane_engine_program_equivalence_via_public_api() {
    // The same assembled program, run on a LUT-mode and an arithmetic-mode
    // machine, must leave bit-identical register state — the public-API
    // form of the lane-engine equivalence gate.
    let prog = assemble(
        "
        VMULPT16  v2, v0, v1
        VADDPT16  v3, v2, v0
        VCMPPT16  k1, v3, v2, 6
        VADDPT16  v4{k1}{z}, v3, v1
        VCVTPT162PS16 v5, v3
        ",
    )
    .unwrap();
    let mut rng = Rng::new(0x1A7E5);
    let t = LaneType::Takum(16);
    let vals_a: Vec<f64> = (0..32).map(|_| rng.wide_f64(-30, 30)).collect();
    let vals_b: Vec<f64> = (0..32).map(|_| rng.wide_f64(-30, 30)).collect();
    let mut fast = EngineConfig::from_env().codec(CodecMode::Lut).build().unwrap().machine();
    let mut slow = EngineConfig::from_env().codec(CodecMode::Arith).build().unwrap().machine();
    for m in [&mut fast, &mut slow] {
        m.load_f64(0, t, &vals_a);
        m.load_f64(1, t, &vals_b);
        m.run(&prog).unwrap();
    }
    for r in 0..6 {
        assert_eq!(fast.regs.v[r], slow.regs.v[r], "v{r}");
    }
    assert_eq!(fast.get_mask(1), slow.get_mask(1));
    assert_eq!(fast.executed, slow.executed);

    // End-to-end GEMM: identical error and instruction stream.
    let lut_eng = EngineConfig::from_env().codec(CodecMode::Lut).build().unwrap();
    let arith_eng = EngineConfig::from_env().codec(CodecMode::Arith).build().unwrap();
    for f in ["t8", "bf16"] {
        let a = gemm(&lut_eng, 16, f, 4, 1.0).unwrap();
        let b = gemm(&arith_eng, 16, f, 4, 1.0).unwrap();
        assert_eq!(a.rel_error.to_bits(), b.rel_error.to_bits(), "{f}");
        assert_eq!(a.executed, b.executed, "{f}");
    }
}

#[test]
fn scaled_gemm_report_renders() {
    let eng = engine();
    let r = gemm_scaled(&eng, 32, "t8", 9, 0.5, 1e4).unwrap();
    assert!(r.rel_error.is_finite());
    let txt = takum_avx10::harness::gemm::run_sim_gemm(&eng, 16, "t8", 9).unwrap();
    assert!(txt.contains("t8") && txt.contains("e4m3") && txt.contains("bf16"));
}
