//! Serving-layer integration tests: the determinism contract (served
//! responses bit-identical to direct `Engine::submit` across every
//! backend × codec at any worker/batch size), shed-path determinism,
//! error fan-out mid-batch, hot-swap under traffic, and the
//! single-start guarantee of the engine-owned PJRT service under
//! concurrent artifact submits.

use std::sync::mpsc;
use takum_avx10::engine::{Engine, EngineConfig, Job};
use takum_avx10::kernels::{Kernel, KernelResult, KernelSpec};
use takum_avx10::runtime::TensorF64;
use takum_avx10::serve::{Rejection, Server, ServerConfig};
use takum_avx10::sim::{Backend, CodecMode};

/// Field-by-field bit identity for kernel results: floats compared on
/// their bit patterns, instruction counts and the full mnemonic
/// histogram exactly.
fn assert_bit_identical(served: &KernelResult, direct: &KernelResult, ctx: &str) {
    assert_eq!(served.kernel, direct.kernel, "{ctx}: kernel");
    assert_eq!(served.format, direct.format, "{ctx}: format");
    assert_eq!(served.n, direct.n, "{ctx}: n");
    assert_eq!(
        served.rel_error.to_bits(),
        direct.rel_error.to_bits(),
        "{ctx}: rel_error bits ({} vs {})",
        served.rel_error,
        direct.rel_error
    );
    assert_eq!(served.executed, direct.executed, "{ctx}: executed");
    assert_eq!(served.dp_instructions, direct.dp_instructions, "{ctx}: dp");
    assert_eq!(served.convert_instructions, direct.convert_instructions, "{ctx}: converts");
    assert_eq!(served.counts, direct.counts, "{ctx}: mnemonic histogram");
}

/// The core serving contract: for every `Backend × CodecMode`, at
/// several server worker counts and batch sizes, every served reply —
/// batched, coalesced or solo — is bit-identical to running the same
/// spec directly on an engine of the same config.
#[test]
fn served_replies_bit_identical_to_direct_submit() {
    for backend in Backend::ALL {
        for codec in CodecMode::ALL {
            let cfg = EngineConfig::new().backend(backend).codec(codec).workers(2);
            let direct = cfg.clone().build().expect("direct engine");

            // Compatible run with duplicates (coalescing) plus a format
            // break mid-stream (batch segmentation).
            let mut specs = Vec::new();
            for kernel in [Kernel::Dot, Kernel::Softmax] {
                for format in ["t8", "bf16"] {
                    for seed in [1u64, 2] {
                        specs.push(KernelSpec { kernel, format, n: 64, seed });
                    }
                }
            }
            specs.push(KernelSpec { kernel: Kernel::Dot, format: "t8", n: 64, seed: 1 }); // dup
            specs.push(KernelSpec { kernel: Kernel::Dot, format: "t8", n: 64, seed: 2 }); // dup

            for (server_workers, batch_max) in [(1usize, 8usize), (3, 2)] {
                let server = Server::start(ServerConfig {
                    tenants: vec![("t".to_string(), cfg.clone())],
                    workers: server_workers,
                    watermark: 256,
                    batch_max,
                })
                .expect("server");
                let (tx, rx) = mpsc::channel();
                let mut by_id = std::collections::HashMap::new();
                for &spec in &specs {
                    let id = server.submit(0, spec, tx.clone()).expect("no shedding here");
                    by_id.insert(id, spec);
                }
                for _ in 0..specs.len() {
                    let reply = rx.recv().expect("reply");
                    let spec = by_id[&reply.id];
                    let ctx = format!(
                        "{}/{} {}/{} n={} seed={} (sw={server_workers}, bm={batch_max})",
                        backend.name(),
                        codec.name(),
                        spec.kernel.name(),
                        spec.format,
                        spec.n,
                        spec.seed
                    );
                    let served = reply.result.expect("kernel must run");
                    let reference = spec.run(&direct).expect("direct run");
                    assert_bit_identical(&served, &reference, &ctx);
                }
                server.shutdown();
            }
        }
    }
}

/// Shed-path determinism: with the gate closed, exactly the first
/// `watermark` submissions are accepted and every overflow sheds with
/// the typed rejection; the accepted prefix then completes in full.
#[test]
fn shed_split_is_deterministic_at_watermark() {
    let server = Server::start(ServerConfig {
        tenants: vec![("t".to_string(), EngineConfig::new().workers(1))],
        workers: 2,
        watermark: 8,
        batch_max: 4,
    })
    .expect("server");
    server.pause();
    let (tx, rx) = mpsc::channel();
    let mut accepted = 0u32;
    let mut shed = 0u32;
    for i in 0..12u64 {
        let spec = KernelSpec { kernel: Kernel::Dot, format: "t8", n: 64, seed: i % 3 };
        match server.submit(0, spec, tx.clone()) {
            Ok(_) => {
                assert!(i < 8, "acceptance must be the prefix, got id at position {i}");
                accepted += 1;
            }
            Err(Rejection::Shed { depth, watermark }) => {
                assert!(i >= 8, "shed before the watermark at position {i}");
                assert_eq!((depth, watermark), (8, 8));
                shed += 1;
            }
            Err(Rejection::Closed) => panic!("server is running"),
        }
    }
    assert_eq!((accepted, shed), (8, 4));
    assert_eq!(server.queue_depth(), 8);
    server.resume();
    for _ in 0..8 {
        let reply = rx.recv().expect("accepted requests complete");
        assert!(reply.result.is_ok());
    }
    #[cfg(not(feature = "telemetry-off"))]
    {
        let snap = server.tenant_engine(0).telemetry();
        assert_eq!(snap.serve_enqueued, 8);
        assert_eq!(snap.serve_shed, 4);
        assert!(snap.serve_batched >= 2, "8 accepted / batch_max 4 needs >= 2 batches");
    }
    server.shutdown();
}

/// `Engine::run_tasks` with a task failing mid-fan-out: the abort
/// drains cleanly (no hang, no poisoned pool), the first error comes
/// back, and the pool immediately serves a full fan-out afterwards with
/// per-worker counts summing to the task count.
#[test]
fn run_tasks_error_mid_fanout_drains_and_recovers() {
    let eng = EngineConfig::new().workers(4).build().expect("engine");
    let err = eng
        .run_tasks(64, |i| {
            if i >= 20 {
                anyhow::bail!("task {i} exploded")
            }
            Ok(i * 2)
        })
        .expect_err("mid-fan-out failure must surface");
    assert!(err.to_string().contains("exploded"), "{err:#}");

    // The pool survives: a following fan-out completes with every slot
    // filled and the per-worker counts accounting for every task.
    let (results, per_worker) = eng.run_tasks(64, |i| Ok(i + 1)).expect("clean run");
    assert_eq!(results, (1..=64).collect::<Vec<_>>());
    assert_eq!(per_worker.len(), 4);
    assert_eq!(per_worker.iter().sum::<usize>(), 64, "per-worker counts must sum");
}

/// A batch that fails mid-fan-out (invalid sizes force a kernel error)
/// fans the same deterministic error to every member, and the server
/// keeps serving afterwards.
#[test]
fn batch_error_fans_out_to_every_member() {
    let server = Server::start(ServerConfig {
        tenants: vec![("t".to_string(), EngineConfig::new().workers(2))],
        workers: 1,
        watermark: 5,
        batch_max: 5,
    })
    .expect("server");
    server.pause();
    let (tx, rx) = mpsc::channel();
    // Five distinct specs (no coalescing) at an off-tile size: the batch
    // fan-out hits the kernel-size contract and aborts.
    for seed in 0..5u64 {
        let spec = KernelSpec { kernel: Kernel::Dot, format: "t8", n: 32, seed };
        server.submit(0, spec, tx.clone()).expect("under watermark");
    }
    server.resume();
    let mut messages = Vec::new();
    for _ in 0..5 {
        let reply = rx.recv().expect("reply");
        messages.push(reply.result.expect_err("n=32 must fail"));
        assert!(!reply.coalesced);
    }
    assert!(messages[0].contains("multiple of 64"), "{}", messages[0]);
    assert!(messages.iter().all(|m| m == &messages[0]), "error must fan out identically");

    // The failed batch did not wedge the worker.
    let spec = KernelSpec { kernel: Kernel::Dot, format: "t8", n: 64, seed: 1 };
    server.submit(0, spec, tx).expect("server still accepts");
    assert!(rx.recv().expect("reply").result.is_ok());
    server.shutdown();
}

/// Hot-swapping a tenant while a producer hammers it loses no requests:
/// every reply arrives Ok (old engine finishes its in-flight batches,
/// new engine takes over), and the tenant ends on the new config.
#[test]
fn hot_swap_under_traffic_loses_nothing() {
    let server = Server::start(ServerConfig {
        tenants: vec![("t".to_string(), EngineConfig::new().workers(2))],
        workers: 2,
        watermark: 1024,
        batch_max: 8,
    })
    .expect("server");
    let total = 200u64;
    std::thread::scope(|scope| {
        let server = &server;
        let consumer = scope.spawn(move || {
            let (tx, rx) = mpsc::channel();
            for i in 0..total {
                let spec = KernelSpec { kernel: Kernel::Dot, format: "t8", n: 64, seed: i % 3 };
                server.submit(0, spec, tx.clone()).expect("under watermark");
            }
            let mut ok = 0u64;
            for _ in 0..total {
                if rx.recv().expect("reply").result.is_ok() {
                    ok += 1;
                }
            }
            ok
        });
        // Swap mid-traffic: first onto the arith codec, then onto the
        // vector backend. Old engines stay alive for their in-flight
        // batches; new batches run the new config.
        server
            .swap_tenant(0, EngineConfig::new().workers(2).codec(CodecMode::Arith))
            .expect("swap 1");
        server
            .swap_tenant(
                0,
                EngineConfig::new().workers(2).backend(Backend::Vector),
            )
            .expect("swap 2");
        assert_eq!(consumer.join().expect("producer"), total, "every request must complete");
    });
    assert!(
        server.tenant_engine(0).tag().contains("backend=vector"),
        "tenant must end on the swapped-in config, got {}",
        server.tenant_engine(0).tag()
    );
    server.shutdown();
}

/// Concurrent `Job::Artifact` submits race the lazy PJRT service start:
/// the start-outside-lock/install-under-lock protocol runs the
/// constructor exactly once, and every submitter gets a working handle
/// (the graph-interpreter fallback without the `pjrt` feature).
#[test]
fn pjrt_service_starts_exactly_once_under_concurrent_artifact_submits() {
    let eng = EngineConfig::new().workers(2).build().expect("engine");
    let eng: &Engine = &eng;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                scope.spawn(move || {
                    eng.submit(Job::Artifact {
                        name: "takum8_roundtrip".into(),
                        inputs: vec![TensorF64::vec(vec![1.0, 2.5, -3.0 - i as f64])],
                    })
                    .map(|r| r.artifact())
                })
            })
            .collect();
        for h in handles {
            let out = h.join().expect("no panic").expect("artifact job");
            assert_eq!(out[0].len(), 3);
        }
    });
    assert_eq!(eng.pjrt_starts(), 1, "the service must start exactly once");
}
