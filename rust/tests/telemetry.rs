//! Integration tests for the engine-wide telemetry layer: counters are
//! deterministic functions of the submitted work, the span recorder
//! emits one span per lifecycle stage per job regardless of job kind,
//! the Chrome-trace export is well-formed, and snapshots survive the
//! persist/parse round trip the `stats` subcommand depends on.
//!
//! The whole file is gated on telemetry being compiled in: under
//! `--features telemetry-off` every counter is a no-op by design.
#![cfg(not(feature = "telemetry-off"))]

use takum_avx10::engine::{EngineConfig, GemmJob, Job};
use takum_avx10::kernels::{Kernel, KernelSpec};
use takum_avx10::sim::{Instruction, Operand, Program};
use takum_avx10::telemetry::{Stage, TelemetrySnapshot};
use takum_avx10::util::json::Json;
use takum_avx10::verify::Externals;

fn kernel_spec() -> KernelSpec {
    KernelSpec { kernel: Kernel::Softmax, format: "e4m3", n: 128, seed: 7 }
}

/// Zero out the wall-clock-dependent parts of a snapshot so the
/// remainder can be compared for exact equality across runs.
fn counters_only(mut s: TelemetrySnapshot) -> TelemetrySnapshot {
    s.stages.clear();
    s
}

/// Telemetry counters are exact, reproducible functions of
/// `(kernel, format, n, seed)` — two fresh engines running the same job
/// produce identical counter snapshots, and the snapshot agrees with the
/// job's own result metrics.
#[test]
fn counters_are_deterministic_and_match_the_result() {
    let run = || {
        let eng = EngineConfig::new().workers(2).build().unwrap();
        let r = eng.submit(Job::Kernel(kernel_spec())).unwrap().kernel();
        (eng.telemetry(), r)
    };
    let (snap_a, result) = run();
    let (snap_b, _) = run();
    assert_eq!(
        counters_only(snap_a.clone()),
        counters_only(snap_b),
        "same job on a fresh engine must produce identical counters"
    );

    assert_eq!(snap_a.jobs, 1);
    // One kernel job absorbs exactly one machine: the snapshot's
    // executed-mnemonic histogram IS the result's.
    assert_eq!(snap_a.executed, result.executed);
    assert_eq!(
        snap_a.mnemonics,
        result.counts,
        "snapshot histogram must match the kernel result's"
    );
    // The e4m3 pipeline pays storage↔compute converts; the class
    // decomposition counts every Convert-plan execution, which includes
    // the result's cvt_in/cvt_out subset.
    let class_converts = snap_a.classes.get("convert").copied().unwrap_or(0);
    assert!(
        class_converts >= result.convert_instructions && result.convert_instructions > 0,
        "convert class {class_converts} must cover the result's {}",
        result.convert_instructions
    );
    assert_eq!(snap_a.converts, class_converts, "headline converts = class counter");
    // Hot-path cache counters: repeated mnemonics hit the plan cache,
    // repeated tile reads hit the decoded shadow.
    assert!(snap_a.plan_hits > 0, "{snap_a:?}");
    assert!(snap_a.shadow_hits > 0, "{snap_a:?}");
    // Policy Off ⇒ the cell counts one skipped verify outcome.
    assert_eq!(
        (snap_a.verify_skipped, snap_a.verify_clean, snap_a.verify_denied),
        (1, 0, 0),
        "{snap_a:?}"
    );
}

/// Every job kind emits exactly one span per lifecycle stage (fused
/// stages appear as zero-duration markers), so the per-stage counts all
/// equal the number of submitted jobs.
#[test]
fn every_job_kind_records_one_span_per_stage() {
    let eng = EngineConfig::new().workers(1).build().unwrap();
    eng.submit(Job::Kernel(kernel_spec())).unwrap();
    eng.submit(Job::Gemm(GemmJob::new(16, "t8"))).unwrap();
    let mut prog = Program::default();
    prog.push(Instruction::new(
        "VADDPT8",
        Operand::Vreg(2),
        vec![Operand::Vreg(0), Operand::Vreg(1)],
    ));
    eng.submit(Job::Program { prog, externals: Externals::new() }).unwrap();

    let snap = eng.telemetry();
    assert_eq!(snap.jobs, 3);
    assert_eq!(snap.stages.len(), Stage::ALL.len());
    for stage in &snap.stages {
        assert_eq!(
            stage.count, 3,
            "stage {} must have one span per submitted job: {snap:?}",
            stage.stage
        );
    }
}

/// The Chrome-trace export of a real engine run: valid JSON, one
/// complete-phase event per stage per job, timestamps sorted.
#[test]
fn chrome_trace_covers_the_lifecycle_per_job() {
    let eng = EngineConfig::new().workers(1).build().unwrap();
    let jobs = 2usize;
    for seed in 0..jobs as u64 {
        let spec = KernelSpec { seed, ..kernel_spec() };
        eng.submit(Job::Kernel(spec)).unwrap();
    }
    let trace = eng.chrome_trace();
    let doc = Json::parse(&trace).expect("chrome trace must be valid JSON");
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    assert_eq!(events.len(), jobs * Stage::ALL.len(), "one event per stage per job");
    let mut last_ts = f64::MIN;
    for e in events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(e.get("cat").and_then(Json::as_str), Some("kernel"));
        let ts = e.get("ts").and_then(Json::as_f64).expect("ts");
        assert!(ts >= last_ts, "trace events must be sorted by ts");
        last_ts = ts;
    }
    for st in Stage::ALL {
        let per_stage = events
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some(st.name()))
            .count();
        assert_eq!(per_stage, jobs, "stage {} once per job", st.name());
    }
}

/// The cross-process flow behind `takum-avx10 stats`: a snapshot written
/// to disk parses back into an identical value.
#[test]
fn snapshot_survives_the_persist_round_trip() {
    let eng = EngineConfig::new().workers(2).build().unwrap();
    eng.submit(Job::Kernel(kernel_spec())).unwrap();
    let snap = eng.telemetry();

    let dir = std::env::temp_dir().join("takum-telemetry-roundtrip-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("takum-stats.json");
    std::fs::write(&path, snap.to_json()).unwrap();
    let parsed =
        TelemetrySnapshot::from_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(parsed, snap);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Suite jobs exercise the fold paths the single-cell test cannot: many
/// absorbed machines accumulate, and the shared plan cache turns later
/// cells' first lookups into hits (the hit rate climbs with reuse).
#[test]
fn suite_jobs_accumulate_across_cells() {
    let eng = EngineConfig::new().workers(1).build().unwrap();
    let results = eng.submit(Job::Suite { n: 64, seed: Some(3) }).unwrap().suite();
    let snap = eng.telemetry();
    assert_eq!(snap.jobs, 1);
    let total: u64 = results.iter().map(|r| r.executed).sum();
    assert_eq!(snap.executed, total, "suite snapshot sums every cell's machine");
    // One verify outcome per cell (policy Off ⇒ all skipped).
    assert_eq!(snap.verify_skipped, results.len() as u64, "{snap:?}");
    assert!(snap.plan_hit_rate().unwrap_or(0.0) > 50.0, "plan reuse must dominate: {snap:?}");
}
