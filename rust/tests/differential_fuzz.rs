//! Cross-backend differential fuzz suite: seeded random mixed-format
//! kernel programs executed across `Backend::{Scalar, Vector, Graph}` ×
//! `CodecMode::{Lut, Arith}` must leave **bit-identical** architectural
//! state (all 32 vector registers and all 8 mask registers), and the
//! HLO-lite graph interpreter (`Graph::lift` → `optimize` → `run_on`)
//! must reproduce the machine replay of every liftable program exactly.
//! A further axis forces the vector backend through every SIMD tier the
//! host supports (`sim::simd::Tier`) — specialised lane kernels are held
//! bit-identical to the scalar reference, NaR/NaN canonicalisation
//! included.
//!
//! Every machine here is built through `engine::EngineConfig`/`Engine` —
//! the unified execution context — so the corpus simultaneously pins the
//! front door itself: an engine-built machine in any config must be
//! bit-identical to every other config's.
//!
//! The program generator is a plain LCG (no external deps, no shared
//! `Rng` state): every test derives everything — instruction sequence,
//! operand registers, lane values (including NaN/±inf payload lanes),
//! write masks, zeroing flags — from one `u64` seed. The seed set is
//! fixed, so CI failures are reproducible by construction; on mismatch
//! the failing seed is printed so it can be pinned into `SEEDS` as a
//! regression.

use takum_avx10::engine::{Engine, EngineConfig};
use takum_avx10::kernels::run_suite;
use takum_avx10::num::{BF16, E4M3, E5M2, F16, F32};
use takum_avx10::sim::{
    Backend, CodecMode, Graph, Instruction, LaneType, Machine, Operand, Program, Tier, VecReg,
};
use takum_avx10::verify::{Externals, Verifier};

/// Build the engine for one (mode, backend) config — the front door every
/// machine in this suite comes through (the execution-context redesign's
/// acceptance gate: the fuzz corpus drives *engine-built* machines).
fn engine_for(mode: CodecMode, backend: Backend) -> Engine {
    EngineConfig::new().codec(mode).backend(backend).build().unwrap()
}

/// The fixed fuzz corpus: 32 seeds for each tier (the acceptance floor).
/// To reproduce a CI failure locally, the failing seed is printed in the
/// panic message — add it here to pin it.
const SEEDS: [u64; 32] = [
    0x0001, 0x0002, 0x0003, 0x0004, 0x0005, 0x0006, 0x0007, 0x0008, 0x1009, 0x100A, 0x100B,
    0x100C, 0x100D, 0x100E, 0x100F, 0x1010, 0x2BAD, 0x2BEE, 0x2C0D, 0x2CAB, 0x3D05, 0x3E11,
    0x3F22, 0x4A40, 0x5B55, 0x6C66, 0x7D77, 0x8E88, 0x9F99, 0xAAAA, 0xBEEF, 0xCAFE,
];

// ---------------------------------------------------------------------------
// LCG + generator
// ---------------------------------------------------------------------------

/// Knuth's MMIX LCG; draws use the high 32 bits (the low bits of an LCG
/// cycle with short periods).
struct Lcg(u64);

impl Lcg {
    fn new(seed: u64) -> Lcg {
        // One warm-up step so small seeds diverge immediately.
        let mut l = Lcg(seed ^ 0x5DEE_CE66_D1CE_4E5B);
        l.next32();
        l
    }

    fn next32(&mut self) -> u32 {
        self.0 = self
            .0
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(1_442_695_040_888_963_407);
        (self.0 >> 32) as u32
    }

    /// Uniform in `[0, n)`.
    fn below(&mut self, n: u32) -> u32 {
        ((self.next32() as u64 * n as u64) >> 32) as u32
    }

    fn coin(&mut self, num: u32, den: u32) -> bool {
        self.below(den) < num
    }

    /// A lane value: mostly finite (mantissa in [1,2) × 2^e, e ∈
    /// [-20, 20], sign-symmetric), with occasional NaN/±inf/±0 payloads.
    fn lane(&mut self) -> f64 {
        if self.coin(1, 12) {
            return match self.below(5) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                3 => 0.0,
                _ => -0.0,
            };
        }
        let mant = 1.0 + self.next32() as f64 / (1u64 << 32) as f64;
        let e = self.below(41) as i32 - 20;
        let sign = if self.coin(1, 2) { -1.0 } else { 1.0 };
        sign * mant * (e as f64).exp2()
    }
}

/// The 6 lane formats of the suite, by arithmetic-mnemonic suffix.
const FORMATS: [(&str, LaneType); 6] = [
    ("PT8", LaneType::Takum(8)),
    ("PT16", LaneType::Takum(16)),
    ("HF8", LaneType::Mini(E4M3)),
    ("BF8", LaneType::Mini(E5M2)),
    ("PH", LaneType::Mini(F16)),
    ("NEPBF16", LaneType::Mini(BF16)),
];

/// A generated test case: initial loads + mask values + the program.
struct Case {
    loads: Vec<(u8, LaneType, Vec<f64>)>,
    masks: [(u8, u64); 3],
    prog: Program,
}

impl Case {
    /// Build a fresh engine-configured machine with the case's initial
    /// state installed.
    fn machine(&self, engine: &Engine) -> Machine {
        let mut m = engine.machine();
        for (reg, ty, vals) in &self.loads {
            m.load_f64(*reg, *ty, vals);
        }
        for (k, bits) in self.masks {
            m.set_mask(k, bits);
        }
        m
    }
}

/// Generate a random mixed-format program. `liftable_only` restricts the
/// vocabulary to the HLO-lite fp dataflow subset (`Graph::lift`'s
/// domain): no compares (they write mask registers) and type-consistent
/// register reuse; the full tier additionally emits compares and
/// type-punning reads to stress the raw decode paths.
fn generate(seed: u64, liftable_only: bool) -> Case {
    let mut r = Lcg::new(seed);
    let (sfx, ty) = FORMATS[r.below(6) as usize];
    let lanes = VecReg::lanes(ty.width());

    // Initial state: registers 0..6 hold random planes of the primary
    // format (NaN/inf lanes included).
    let mut loads = Vec::new();
    let mut reg_ty: [Option<LaneType>; 16] = [None; 16];
    for reg in 0u8..6 {
        let vals: Vec<f64> = (0..lanes).map(|_| r.lane()).collect();
        loads.push((reg, ty, vals));
        reg_ty[reg as usize] = Some(ty);
    }
    let masks = [
        (1u8, ((r.next32() as u64) << 32) | r.next32() as u64),
        (2u8, ((r.next32() as u64) << 32) | r.next32() as u64),
        (3u8, u64::MAX), // one dense mask so merging stays exercised
    ];

    // Register picks: `pick` returns a register safe for the lifter
    // (holds `want` or is untouched). Type-introducing arms must check
    // `has_slot` first: with 16 registers and up to 8 live types, a
    // freshly drawn destination type can otherwise have no candidate
    // left (seed 0xBEEF used to reach exactly that and panic).
    let has_slot = |reg_ty: &[Option<LaneType>; 16], want: LaneType| -> bool {
        reg_ty.iter().any(|t| t.is_none() || *t == Some(want))
    };
    let pick = |r: &mut Lcg, reg_ty: &[Option<LaneType>; 16], want: LaneType| -> u8 {
        let candidates: Vec<u8> = (0u8..16)
            .filter(|&i| reg_ty[i as usize].is_none() || reg_ty[i as usize] == Some(want))
            .collect();
        assert!(!candidates.is_empty(), "no register slot for {want:?}");
        candidates[r.below(candidates.len() as u32) as usize]
    };

    let mut prog = Program::default();
    let n_ins = 8 + r.below(17);
    for _ in 0..n_ins {
        let masked = r.coin(1, 3);
        let mask = if masked { 1 + r.below(3) as u8 } else { 0 };
        let zeroing = masked && r.coin(1, 2);
        let with_mask = |ins: Instruction| -> Instruction {
            if masked {
                ins.with_mask(mask, zeroing)
            } else {
                ins
            }
        };
        // Liftable tier: arms 0..=8 (arithmetic, converts, dots). Full
        // tier adds arm 9 (compares + type-punned reads).
        let kind_space = if liftable_only { 9 } else { 10 };
        match r.below(kind_space) {
            // Packed binary arithmetic in the primary format.
            0..=3 => {
                let op = ["VADD", "VSUB", "VMUL", "VDIV", "VMIN", "VMAX"]
                    [r.below(6) as usize];
                let (a, b) = (pick(&mut r, &reg_ty, ty), pick(&mut r, &reg_ty, ty));
                let dst = pick(&mut r, &reg_ty, ty);
                prog.push(with_mask(Instruction::new(
                    &format!("{op}{sfx}"),
                    Operand::Vreg(dst),
                    vec![Operand::Vreg(a), Operand::Vreg(b)],
                )));
                reg_ty[dst as usize] = Some(ty);
            }
            // FMA family (reads dst as the third operand).
            4..=5 => {
                let mn = ["VFMADD", "VFMSUB", "VFNMADD", "VFNMSUB"][r.below(4) as usize];
                let ord = ["132", "213", "231"][r.below(3) as usize];
                let (a, b) = (pick(&mut r, &reg_ty, ty), pick(&mut r, &reg_ty, ty));
                let dst = pick(&mut r, &reg_ty, ty);
                prog.push(with_mask(Instruction::new(
                    &format!("{mn}{ord}{sfx}"),
                    Operand::Vreg(dst),
                    vec![Operand::Vreg(a), Operand::Vreg(b)],
                )));
                reg_ty[dst as usize] = Some(ty);
            }
            // VRNDSCALE with a random fixed-point scale.
            6 => {
                let a = pick(&mut r, &reg_ty, ty);
                let dst = pick(&mut r, &reg_ty, ty);
                prog.push(with_mask(Instruction::new(
                    &format!("VRNDSCALE{sfx}"),
                    Operand::Vreg(dst),
                    vec![Operand::Vreg(a), Operand::Imm((r.below(4) as i64) << 4)],
                )));
                reg_ty[dst as usize] = Some(ty);
            }
            // Cross-format convert (the mixed-format requirement). Falls
            // back to a same-type convert when no register slot is left
            // for the drawn destination type (the primary type always
            // has slots: its six initial registers never retype).
            7 => {
                let (mut dsfx, mut dty) = FORMATS[r.below(6) as usize];
                if !has_slot(&reg_ty, dty) {
                    (dsfx, dty) = (sfx, ty);
                }
                let a = pick(&mut r, &reg_ty, ty);
                let dst = pick(&mut r, &reg_ty, dty);
                prog.push(with_mask(Instruction::new(
                    &format!("VCVT{sfx}2{dsfx}"),
                    Operand::Vreg(dst),
                    vec![Operand::Vreg(a)],
                )));
                reg_ty[dst as usize] = Some(dty);
            }
            // Widening dot product into a dedicated wide accumulator.
            8 => {
                let dp_wide: Option<(&str, LaneType)> = match ty {
                    LaneType::Takum(8) => Some(("VDPPT8PT16", LaneType::Takum(16))),
                    LaneType::Takum(16) => Some(("VDPPT16PT32", LaneType::Takum(32))),
                    LaneType::Mini(s) if s == BF16 => Some(("VDPBF16PS", LaneType::Mini(F32))),
                    LaneType::Mini(s) if s == F16 => Some(("VDPPHPS", LaneType::Mini(F32))),
                    // OFP8 has no dp.
                    _ => None,
                };
                match dp_wide {
                    // Only when a register slot remains for the wide
                    // accumulator type (see `has_slot`).
                    Some((dp, wide)) if has_slot(&reg_ty, wide) => {
                        let (a, b) = (pick(&mut r, &reg_ty, ty), pick(&mut r, &reg_ty, ty));
                        let dst = pick(&mut r, &reg_ty, wide);
                        prog.push(with_mask(Instruction::new(
                            dp,
                            Operand::Vreg(dst),
                            vec![Operand::Vreg(a), Operand::Vreg(b)],
                        )));
                        reg_ty[dst as usize] = Some(wide);
                    }
                    // Fall back to a compare-free binary in the primary
                    // format.
                    _ => {
                        let (a, b) = (pick(&mut r, &reg_ty, ty), pick(&mut r, &reg_ty, ty));
                        let dst = pick(&mut r, &reg_ty, ty);
                        prog.push(with_mask(Instruction::new(
                            &format!("VMUL{sfx}"),
                            Operand::Vreg(dst),
                            vec![Operand::Vreg(a), Operand::Vreg(b)],
                        )));
                        reg_ty[dst as usize] = Some(ty);
                    }
                }
            }
            // Full tier only: compares (write k4..k7) and a type-punned
            // read (decode arbitrary bit patterns as the primary format).
            9 => {
                if r.coin(1, 2) {
                    let pred = [0i64, 1, 2, 4, 5, 6][r.below(6) as usize];
                    let (a, b) = (r.below(16) as u8, r.below(16) as u8);
                    prog.push(Instruction::new(
                        &format!("VCMP{sfx}"),
                        Operand::Kreg(4 + r.below(4) as u8),
                        vec![Operand::Vreg(a), Operand::Vreg(b), Operand::Imm(pred)],
                    ));
                } else {
                    // Read whatever bits happen to live in any register.
                    let (a, b) = (r.below(16) as u8, r.below(16) as u8);
                    let dst = r.below(16) as u8;
                    prog.push(with_mask(Instruction::new(
                        &format!("VADD{sfx}"),
                        Operand::Vreg(dst),
                        vec![Operand::Vreg(a), Operand::Vreg(b)],
                    )));
                    reg_ty[dst as usize] = Some(ty);
                }
            }
            _ => unreachable!(),
        }
    }
    Case { loads, masks, prog }
}

/// Every (mode, backend) config the suite crosses.
const CONFIGS: [(CodecMode, Backend); 6] = [
    (CodecMode::Lut, Backend::Scalar),
    (CodecMode::Lut, Backend::Vector),
    (CodecMode::Lut, Backend::Graph),
    (CodecMode::Arith, Backend::Scalar),
    (CodecMode::Arith, Backend::Vector),
    (CodecMode::Arith, Backend::Graph),
];

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

/// The headline differential gate: for every seed, every backend × codec
/// mode leaves bit-identical register planes and mask registers.
#[test]
fn cross_backend_bit_identity_on_random_programs() {
    let engines: Vec<(CodecMode, Backend, Engine)> =
        CONFIGS.iter().map(|&(m, b)| (m, b, engine_for(m, b))).collect();
    let reference_engine = engine_for(CodecMode::Lut, Backend::Scalar);
    for &seed in &SEEDS {
        let case = generate(seed, false);
        let mut reference = case.machine(&reference_engine);
        reference
            .run(&case.prog)
            .unwrap_or_else(|e| panic!("seed={seed:#x}: reference run failed: {e}"));
        for (mode, backend, eng) in &engines {
            let (mode, backend) = (*mode, *backend);
            let mut m = case.machine(eng);
            m.run(&case.prog)
                .unwrap_or_else(|e| panic!("seed={seed:#x} {mode:?}/{backend:?}: {e}"));
            for reg in 0..32 {
                assert_eq!(
                    reference.regs.v[reg], m.regs.v[reg],
                    "DIFFERENTIAL MISMATCH seed={seed:#x} {mode:?}/{backend:?} v{reg} \
                     (pin this seed in SEEDS to reproduce)"
                );
            }
            for k in 0..8 {
                assert_eq!(
                    reference.regs.k[k], m.regs.k[k],
                    "DIFFERENTIAL MISMATCH seed={seed:#x} {mode:?}/{backend:?} k{k}"
                );
            }
            assert_eq!(reference.executed, m.executed, "seed={seed:#x}");
        }
    }
}

/// The SIMD-tier differential gate: the same corpus run on the vector
/// backend forced through every tier this host supports must leave
/// bit-identical architectural state to the scalar/LUT reference. This
/// holds the whole tier cascade (`sim::simd`) — AVX-512 gathers, AVX2
/// lane kernels, the generic `LANES` instantiations — to the one
/// contract that matters: a tier is a speed, never a value. NaN payload
/// lanes from the generator make this simultaneously the NaR-contract
/// fuzz axis: every tier must canonicalise NaN to the format's NaR/NaN
/// pattern identically, or a v-reg compare fails.
#[test]
fn cross_tier_bit_identity_on_random_programs() {
    let tiers = Tier::supported();
    assert!(
        tiers.contains(&Tier::Scalar),
        "Tier::supported() must always include the scalar anchor"
    );
    let engines: Vec<(Tier, Engine)> = tiers
        .iter()
        .map(|&tier| {
            let eng = EngineConfig::new()
                .codec(CodecMode::Lut)
                .backend(Backend::Vector)
                .simd(tier)
                .build()
                .unwrap_or_else(|e| panic!("building forced-{} engine: {e}", tier.name()));
            assert_eq!(eng.simd(), tier, "forced tier must stick through build()");
            (tier, eng)
        })
        .collect();
    let reference_engine = engine_for(CodecMode::Lut, Backend::Scalar);
    for &seed in &SEEDS {
        let case = generate(seed, false);
        let mut reference = case.machine(&reference_engine);
        reference
            .run(&case.prog)
            .unwrap_or_else(|e| panic!("seed={seed:#x}: reference run failed: {e}"));
        for (tier, eng) in &engines {
            let mut m = case.machine(eng);
            assert_eq!(m.tier(), *tier, "machine must dispatch through the forced tier");
            m.run(&case.prog)
                .unwrap_or_else(|e| panic!("seed={seed:#x} simd={}: {e}", tier.name()));
            for reg in 0..32 {
                assert_eq!(
                    reference.regs.v[reg],
                    m.regs.v[reg],
                    "TIER MISMATCH seed={seed:#x} simd={} v{reg} \
                     (pin this seed in SEEDS to reproduce)",
                    tier.name()
                );
            }
            for k in 0..8 {
                assert_eq!(
                    reference.regs.k[k],
                    m.regs.k[k],
                    "TIER MISMATCH seed={seed:#x} simd={} k{k}",
                    tier.name()
                );
            }
            assert_eq!(reference.executed, m.executed, "seed={seed:#x} simd={}", tier.name());
        }
    }
}

/// The graph-interpreter gate: lifting a liftable program and evaluating
/// the optimised graph must equal the machine replay bit for bit, in
/// both codec modes (and the passes must actually fire over the corpus).
#[test]
fn lifted_interpreter_matches_machine_replay() {
    let mut total_folded = 0usize;
    let mut total_dead = 0usize;
    let scalar_lut = engine_for(CodecMode::Lut, Backend::Scalar);
    let scalar_arith = engine_for(CodecMode::Arith, Backend::Scalar);
    for &seed in &SEEDS {
        let case = generate(seed, true);
        let init = case.machine(&scalar_lut).regs.clone();
        let mut graph = Graph::lift(&case.prog, &init)
            .unwrap_or_else(|e| panic!("seed={seed:#x}: lift failed: {e}"));
        let stats = graph.optimize();
        total_folded += stats.converts_folded;
        total_dead += stats.dead_removed;
        for mode in [CodecMode::Lut, CodecMode::Arith] {
            let eng = if mode == CodecMode::Lut { &scalar_lut } else { &scalar_arith };
            let mut mach = eng.machine();
            mach.regs = init.clone();
            mach.run(&case.prog)
                .unwrap_or_else(|e| panic!("seed={seed:#x} {mode:?}: replay failed: {e}"));
            let got = graph
                .run_on(&init, mode)
                .unwrap_or_else(|e| panic!("seed={seed:#x} {mode:?}: graph eval failed: {e}"));
            for reg in 0..32 {
                assert_eq!(
                    mach.regs.v[reg], got.v[reg],
                    "GRAPH MISMATCH seed={seed:#x} {mode:?} v{reg} \
                     (pin this seed in SEEDS to reproduce)"
                );
            }
        }
    }
    // The lifter folds redundant quantising converts *at construction*
    // (`Lifter::read`): a convert chain never materialises as graph
    // nodes in the first place, so the cleanup pass must find nothing
    // left to fold — over a corpus full of VCVT chains. The dead-plane
    // pass still has real work (overwritten registers).
    assert!(
        total_folded == 0,
        "lift construction left {total_folded} redundant converts for the pass to fold"
    );
    assert!(total_dead > 0, "no dead planes eliminated across the corpus");
}

/// The graph-compiler gate (`crate::opt`): for every liftable corpus
/// seed, lift → exact rewrite fixpoint → lower → replay must leave
/// architectural state bit-identical to the direct machine replay, in
/// every `Backend × CodecMode` config — and every lowered program must
/// pass the static verifier under `Verify::Deny` semantics. This is the
/// soundness pin behind the engine's `--opt on` axis: the optimizer may
/// only erase work, never change a bit.
#[test]
fn optimized_lowering_bit_identity() {
    use takum_avx10::opt::{lower, run_lowered, Optimizer};
    let engines: Vec<(CodecMode, Backend, Engine)> =
        CONFIGS.iter().map(|&(m, b)| (m, b, engine_for(m, b))).collect();
    let mut total_applied = 0usize;
    for &seed in &SEEDS {
        let case = generate(seed, true);
        let init = case.machine(&engines[0].2).regs.clone();
        let mut g = Graph::lift(&case.prog, &init)
            .unwrap_or_else(|e| panic!("seed={seed:#x}: lift failed: {e}"));
        let report = Optimizer::exact().run(&mut g);
        assert!(!report.budget_exhausted, "seed={seed:#x}: rule budget tripped");
        total_applied += report.total_applied();
        let low = lower(&g, &init)
            .unwrap_or_else(|e| panic!("seed={seed:#x}: lowering failed: {e}"));
        let verdict = low.verify();
        assert!(
            verdict.passes_deny(),
            "seed={seed:#x}: lowered program fails static verification:\n{}",
            verdict.render_diagnostics()
        );
        for (mode, backend, eng) in &engines {
            let (mode, backend) = (*mode, *backend);
            let mut direct = case.machine(eng);
            direct
                .run(&case.prog)
                .unwrap_or_else(|e| panic!("seed={seed:#x} {mode:?}/{backend:?}: {e}"));
            let mut replay = case.machine(eng);
            run_lowered(&mut replay, &low).unwrap_or_else(|e| {
                panic!("seed={seed:#x} {mode:?}/{backend:?}: lowered replay failed: {e}")
            });
            for reg in 0..32 {
                assert_eq!(
                    direct.regs.v[reg], replay.regs.v[reg],
                    "LOWERING MISMATCH seed={seed:#x} {mode:?}/{backend:?} v{reg} \
                     (pin this seed in SEEDS to reproduce)"
                );
            }
            for k in 0..8 {
                assert_eq!(
                    direct.regs.k[k], replay.regs.k[k],
                    "LOWERING MISMATCH seed={seed:#x} {mode:?}/{backend:?} k{k}"
                );
            }
        }
    }
    // The corpus must drive the rule table, not vacuously pass on
    // rewrite-free graphs.
    assert!(total_applied > 0, "the exact rules never fired across the corpus");
}

/// Static-vs-dynamic differential: for every liftable corpus seed, the
/// static verifier's instruction-mix model (histogram, total, convert
/// and dot counts computed *without executing*) must equal what the
/// machine actually executes — and the corpus must verify clean enough
/// for `Verify::Deny` (dead-write warnings are legitimate in random
/// programs; error-severity diagnostics are not).
#[test]
fn static_verifier_mix_matches_dynamic_execution() {
    let eng = engine_for(CodecMode::Lut, Backend::Scalar);
    for &seed in &SEEDS {
        let case = generate(seed, true);

        // Journal the case's initial state exactly as `Case::machine`
        // installs it: typed loads and mask sets, all before index 0.
        let mut ext = Externals::new();
        for (reg, ty, _) in &case.loads {
            ext.load(0, *reg, *ty);
        }
        for (k, _) in case.masks {
            ext.set_mask(0, k);
        }
        let report =
            Verifier::with_externals(ext).implicit_inputs(true).verify(&case.prog);
        assert!(
            report.passes_deny(),
            "seed={seed:#x}: corpus program has error-severity diagnostics:\n{}",
            report.render_diagnostics()
        );

        // The static histogram is the program histogram (straight-line
        // code: every recorded instruction executes exactly once).
        assert_eq!(
            report.mix.histogram,
            case.prog.histogram(),
            "seed={seed:#x}: static histogram diverged from the program's"
        );
        assert_eq!(report.mix.total, case.prog.len(), "seed={seed:#x}");

        // And it matches the dynamic counters after an actual run.
        let mut m = case.machine(&eng);
        m.run(&case.prog).unwrap_or_else(|e| panic!("seed={seed:#x}: run failed: {e}"));
        assert_eq!(report.mix.total as u64, m.executed, "seed={seed:#x}: total");
        for (&mn, &c) in &report.mix.histogram {
            assert_eq!(
                m.counts.get(mn).copied().unwrap_or(0),
                c as u64,
                "seed={seed:#x}: static count for {mn} diverged from execution"
            );
        }
        let dyn_converts: u64 =
            m.counts.iter().filter(|(m, _)| m.starts_with("VCVT")).map(|(_, c)| c).sum();
        let dyn_dots: u64 =
            m.counts.iter().filter(|(m, _)| m.starts_with("VDP")).map(|(_, c)| c).sum();
        assert_eq!(report.mix.converts as u64, dyn_converts, "seed={seed:#x}: converts");
        assert_eq!(report.mix.dots as u64, dyn_dots, "seed={seed:#x}: dots");
    }
}

/// Telemetry differential: after folding a hand-driven machine into the
/// engine (`Engine::absorb`), the telemetry snapshot's per-mnemonic
/// histogram must equal `Machine::counts` exactly, the class
/// decomposition must account for every executed instruction, and — like
/// every other observable — the counters must be invariant across
/// `Backend × CodecMode` (telemetry is a read-out, never an execution
/// axis).
#[cfg(not(feature = "telemetry-off"))]
#[test]
fn telemetry_counters_match_machine_counts() {
    use std::collections::BTreeMap;
    for &seed in &SEEDS {
        let mut reference: Option<BTreeMap<String, u64>> = None;
        for (mode, backend) in CONFIGS {
            let eng = engine_for(mode, backend);
            let m = case_machine_run(&eng, seed);
            let expect: BTreeMap<String, u64> =
                m.counts.iter().map(|(&mn, &c)| (mn.to_string(), c)).collect();
            eng.absorb(&m);
            let snap = eng.telemetry();
            assert_eq!(
                snap.mnemonics, expect,
                "seed={seed:#x} {mode:?}/{backend:?}: snapshot histogram != machine counts"
            );
            assert_eq!(snap.executed, m.executed, "seed={seed:#x} {mode:?}/{backend:?}");
            assert_eq!(
                snap.classes.values().sum::<u64>(),
                m.executed,
                "seed={seed:#x} {mode:?}/{backend:?}: class decomposition must be total"
            );
            // Absorbing again must double every fold-path counter, not
            // drop or duplicate selectively.
            eng.absorb(&m);
            assert_eq!(eng.telemetry().executed, 2 * m.executed, "seed={seed:#x}");
            match &reference {
                None => reference = Some(expect),
                Some(r) => assert_eq!(
                    r, &expect,
                    "TELEMETRY MISMATCH seed={seed:#x} {mode:?}/{backend:?}: counters must be \
                     invariant across backend × codec configs"
                ),
            }
        }
    }
}

/// Run one corpus case on a fresh engine-built machine (shared helper of
/// the telemetry differential above).
#[cfg(not(feature = "telemetry-off"))]
fn case_machine_run(eng: &Engine, seed: u64) -> Machine {
    let case = generate(seed, false);
    let mut m = case.machine(eng);
    m.run(&case.prog).unwrap_or_else(|e| panic!("seed={seed:#x}: run failed: {e}"));
    m
}

/// Suite-metrics differential: the kernel suite's metrics (relative
/// error bit patterns, executed/dp/convert counts, full mnemonic
/// histograms) are byte-identical across all three backends × both codec
/// modes at n = 64.
#[test]
fn suite_metrics_byte_identical_across_backends_and_modes() {
    const SUITE_SEED: u64 = 0xF077;
    let reference =
        run_suite(&engine_for(CodecMode::Lut, Backend::Scalar), 64, SUITE_SEED).unwrap();
    for (mode, backend) in CONFIGS {
        let got = run_suite(&engine_for(mode, backend), 64, SUITE_SEED).unwrap();
        assert_eq!(reference.len(), got.len());
        for (a, b) in reference.iter().zip(&got) {
            assert_eq!((&a.kernel, &a.format, a.n), (&b.kernel, &b.format, b.n));
            assert_eq!(
                a.rel_error.to_bits(),
                b.rel_error.to_bits(),
                "{}/{} {mode:?}/{backend:?}",
                a.kernel,
                a.format
            );
            assert_eq!(a.executed, b.executed, "{}/{} {mode:?}/{backend:?}", a.kernel, a.format);
            assert_eq!(
                a.dp_instructions, b.dp_instructions,
                "{}/{} {mode:?}/{backend:?}",
                a.kernel, a.format
            );
            assert_eq!(
                a.convert_instructions, b.convert_instructions,
                "{}/{} {mode:?}/{backend:?}",
                a.kernel, a.format
            );
            assert_eq!(a.counts, b.counts, "{}/{} {mode:?}/{backend:?}", a.kernel, a.format);
        }
    }
}
