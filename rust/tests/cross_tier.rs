//! Cross-tier equivalence suite: every SIMD tier this host supports
//! (`sim::simd::Tier`), forced through the `--simd` axis down to scalar,
//! must be **bit-identical** to the scalar/LUT reference on every plane
//! primitive — decode (exhaustive over all bit patterns of every
//! tabulated format), encode (exhaustive takum8/takum16 roundtrips plus
//! the special-value fallback edges), and the packed FMA / widening-dot
//! planes of engine-built machines.
//!
//! This is the acceptance gate of the portable-lane refactor: a tier is
//! a *speed*, never a *value*. The AVX-512 gather decode, the AVX2 lane
//! kernels, and every generic `LANES` instantiation sit behind the same
//! dispatch table (`sim::simd::PlaneKernels`); any divergence from the
//! scalar tier is a kernel bug, and this suite pins the contract on
//! every host CI runs on — including the forced-scalar matrix leg, where
//! `Tier::supported()` still anchors on `Tier::Scalar` and the suite
//! degenerates to a self-check.

use takum_avx10::engine::EngineConfig;
use takum_avx10::num::{BF16, E4M3, E5M2, F16};
use takum_avx10::sim::{
    Backend, CodecMode, Instruction, LaneCodec, LaneType, Operand, Program, Tier, VecReg,
};

/// Every tabulated (LUT-backed) lane format, with its width: the formats
/// whose vector decode/encode planes have specialised tier kernels.
const TABULATED: [(LaneType, u32); 6] = [
    (LaneType::Takum(8), 8),
    (LaneType::Mini(E4M3), 8),
    (LaneType::Mini(E5M2), 8),
    (LaneType::Takum(16), 16),
    (LaneType::Mini(F16), 16),
    (LaneType::Mini(BF16), 16),
];

/// Deterministic value stream for the machine-level tests: mostly
/// moderate finite values, with NaN/±inf/±0 lanes mixed in so the
/// NaR/NaN canonicalisation contract is exercised on every tier.
fn values(seed: u64, n: usize) -> Vec<f64> {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    (0..n)
        .map(|_| {
            s = s.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(1_442_695_040_888_963_407);
            let d = (s >> 32) as u32;
            match d % 16 {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                3 => 0.0,
                4 => -0.0,
                _ => {
                    let mant = 1.0 + (d as f64) / (1u64 << 32) as f64;
                    let e = (d % 31) as i32 - 15;
                    let sign = if d & 0x8000 != 0 { -1.0 } else { 1.0 };
                    sign * mant * (e as f64).exp2()
                }
            }
        })
        .collect()
}

/// Exhaustive decode: every bit pattern of every tabulated format,
/// decoded through every supported tier's vector plane, must match the
/// scalar/LUT reference bit for bit (NaN payloads included — compared
/// via `to_bits`). The arithmetic codec is pinned alongside as a second
/// independent reference, so a LUT-generation bug cannot hide a tier
/// bug (or vice versa).
#[test]
fn exhaustive_decode_bit_identical_across_tiers() {
    for (ty, width) in TABULATED {
        let scalar_lut = LaneCodec::resolve(ty, CodecMode::Lut);
        let scalar_arith = LaneCodec::resolve(ty, CodecMode::Arith);
        let lanes = VecReg::lanes(width);
        let patterns = 1u64 << width;
        let tiered: Vec<(Tier, LaneCodec)> = Tier::supported()
            .iter()
            .map(|&t| (t, LaneCodec::resolve_tiered(ty, CodecMode::Lut, Backend::Vector, t)))
            .collect();
        let mut block = 0u64;
        while block < patterns {
            let n = lanes.min((patterns - block) as usize);
            let mut reg = VecReg::ZERO;
            for i in 0..n {
                reg.set(width, i, block + i as u64);
            }
            let mut reference = [0.0f64; 64];
            scalar_lut.decode_plane(&reg, width, n, &mut reference);
            for i in 0..n {
                let arith = scalar_arith.decode(block + i as u64);
                assert_eq!(
                    reference[i].to_bits(),
                    arith.to_bits(),
                    "{ty:?} LUT vs arithmetic decode disagree on bits {:#x}",
                    block + i as u64
                );
            }
            for (tier, codec) in &tiered {
                let mut got = [0.0f64; 64];
                codec.decode_plane(&reg, width, n, &mut got);
                for i in 0..n {
                    assert_eq!(
                        reference[i].to_bits(),
                        got[i].to_bits(),
                        "TIER DECODE MISMATCH {ty:?} simd={} bits={:#x}",
                        tier.name(),
                        block + i as u64
                    );
                }
            }
            block += n as u64;
        }
    }
}

/// Exhaustive takum roundtrip: decode every takum8 and takum16 bit
/// pattern through the scalar reference, then encode the values back
/// through every tier's vector encode plane. Takum is total and
/// injective, so `encode(decode(b)) == b` for every pattern — including
/// NaR, which decodes to NaN and must re-encode to the NaR pattern on
/// every tier (the boundary-search kernels' NaR fixup lane).
#[test]
fn exhaustive_takum_roundtrip_across_tiers() {
    for n_bits in [8u32, 16] {
        let ty = LaneType::Takum(n_bits);
        let scalar = LaneCodec::resolve(ty, CodecMode::Lut);
        let patterns = 1u64 << n_bits;
        let all: Vec<f64> = (0..patterns).map(|b| scalar.decode(b)).collect();
        for tier in Tier::supported() {
            let codec = LaneCodec::resolve_tiered(ty, CodecMode::Lut, Backend::Vector, tier);
            // Chunked like the machine's encode batches, so every lane
            // position of the lockstep kernels gets hit.
            for (chunk_idx, chunk) in all.chunks(64).enumerate() {
                let mut bits = vec![0u64; chunk.len()];
                codec.encode_slice(chunk, &mut bits);
                for (i, &b) in bits.iter().enumerate() {
                    let expect = chunk_idx as u64 * 64 + i as u64;
                    assert_eq!(
                        b,
                        expect,
                        "TIER ROUNDTRIP MISMATCH takum{n_bits} simd={} bits={expect:#x} \
                         (value {})",
                        tier.name(),
                        chunk[i]
                    );
                }
            }
        }
    }
}

/// Encode special-value edges: NaN, ±inf, ±0, overflow and underflow
/// magnitudes — the values whose encode takes the arithmetic fallback
/// rather than the table sweep. Every tier's batched encode must equal
/// the scalar per-value encode on every tabulated format; NaN in
/// particular must land on the format's NaR/NaN pattern identically.
#[test]
fn encode_specials_bit_identical_across_tiers() {
    let specials = [
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        0.0,
        -0.0,
        1.0,
        -1.0,
        1e30,
        -1e30,
        1e-30,
        -1e-30,
        0.5,
        -2.75,
        65504.0,
        -65504.0,
        3.0e5,
    ];
    for (ty, _) in TABULATED {
        let scalar = LaneCodec::resolve(ty, CodecMode::Lut);
        let expect: Vec<u64> = specials.iter().map(|&x| scalar.encode(x)).collect();
        for tier in Tier::supported() {
            let codec = LaneCodec::resolve_tiered(ty, CodecMode::Lut, Backend::Vector, tier);
            let mut got = vec![0u64; specials.len()];
            codec.encode_slice(&specials, &mut got);
            assert_eq!(
                expect,
                got,
                "TIER ENCODE MISMATCH {ty:?} simd={} on special values",
                tier.name()
            );
        }
    }
}

/// Machine-level FMA and widening-dot planes: the same deterministic
/// program — packed FMA in all three operand orders, the takum widening
/// dots, masked writes — run on an engine forced to every supported
/// tier must leave architectural state bit-identical to the scalar
/// backend's. This drives the tier dispatch through the real
/// `Engine::build` → `Machine` path rather than raw codecs.
#[test]
fn fma_and_dot_planes_bit_identical_across_forced_engines() {
    for (sfx, ty, dp) in [
        ("PT8", LaneType::Takum(8), Some("VDPPT8PT16")),
        ("PT16", LaneType::Takum(16), Some("VDPPT16PT32")),
        ("NEPBF16", LaneType::Mini(BF16), Some("VDPBF16PS")),
        ("PH", LaneType::Mini(F16), Some("VDPPHPS")),
        ("HF8", LaneType::Mini(E4M3), None),
    ] {
        let lanes = VecReg::lanes(ty.width());
        let loads: Vec<(u8, Vec<f64>)> =
            (0u8..5).map(|r| (r, values(0xC0DE + r as u64, lanes))).collect();

        let mut prog = Program::default();
        for (i, (mn, ord)) in [("VFMADD", "132"), ("VFMSUB", "213"), ("VFNMADD", "231")]
            .iter()
            .enumerate()
        {
            prog.push(Instruction::new(
                &format!("{mn}{ord}{sfx}"),
                Operand::Vreg(2 + i as u8),
                vec![Operand::Vreg(0), Operand::Vreg(1)],
            ));
        }
        // A masked, zeroing FMA so the merge path crosses the tier too.
        prog.push(
            Instruction::new(
                &format!("VFNMSUB213{sfx}"),
                Operand::Vreg(4),
                vec![Operand::Vreg(2), Operand::Vreg(3)],
            )
            .with_mask(1, true),
        );
        if let Some(dp) = dp {
            prog.push(Instruction::new(
                dp,
                Operand::Vreg(9),
                vec![Operand::Vreg(0), Operand::Vreg(1)],
            ));
        }

        let run = |cfg: EngineConfig| {
            let eng = cfg.build().unwrap();
            let mut m = eng.machine();
            for (reg, vals) in &loads {
                m.load_f64(*reg, ty, vals);
            }
            m.set_mask(1, 0xAAAA_AAAA_5555_5555);
            m.run(&prog).unwrap_or_else(|e| panic!("{sfx}: {e}"));
            m
        };

        let reference = run(EngineConfig::new().codec(CodecMode::Lut).backend(Backend::Scalar));
        for tier in Tier::supported() {
            let m = run(EngineConfig::new()
                .codec(CodecMode::Lut)
                .backend(Backend::Vector)
                .simd(tier));
            assert_eq!(m.tier(), tier, "{sfx}: machine must run the forced tier");
            for reg in 0..32 {
                assert_eq!(
                    reference.regs.v[reg],
                    m.regs.v[reg],
                    "TIER FMA/DOT MISMATCH {sfx} simd={} v{reg}",
                    tier.name()
                );
            }
        }
    }
}

/// The `--simd` axis end to end: a forced tier sticks through `build()`,
/// is stamped into the engine tag (and therefore into every schema-v3
/// bench JSON `engine_config`), and an unavailable tier is rejected at
/// build time with an error naming the supported set — it never reaches
/// a dispatch table.
#[test]
fn forced_tier_is_stamped_and_unavailable_tiers_rejected() {
    for tier in Tier::supported() {
        let eng = EngineConfig::new().simd(tier).build().unwrap();
        assert_eq!(eng.simd(), tier);
        assert!(
            eng.tag().ends_with(&format!(";simd={}", tier.name())),
            "tag {:?} must stamp the resolved tier",
            eng.tag()
        );
        assert_eq!(eng.machine().tier(), tier);
    }
    for &tier in Tier::ALL.iter().filter(|t| !t.available()) {
        let err = EngineConfig::new().simd(tier).build().unwrap_err().to_string();
        assert!(
            err.contains("not available on this host") && err.contains("scalar"),
            "unavailable tier {:?} must be rejected naming the supported set, got: {err}",
            tier.name()
        );
    }
}
