//! Integration over the unified execution context (`engine::Engine`):
//! the engine-config matrix — every `Backend × CodecMode` combination —
//! must produce byte-identical suite metrics and GEMM results whether
//! work goes through `Engine::submit` or the direct library calls /
//! direct `Machine` stepping, and builder validation must fail with
//! actionable messages.

use takum_avx10::engine::{Engine, EngineConfig, GemmJob, Job};
use takum_avx10::harness::gemm::gemm;
use takum_avx10::kernels::run_suite;
use takum_avx10::sim::{Backend, CodecMode, Instruction, LaneType, Operand};

fn engine_cfg(mode: CodecMode, backend: Backend) -> Engine {
    EngineConfig::new().codec(mode).backend(backend).build().unwrap()
}

/// The full engine-config matrix at n ∈ {64, 128}: `Engine::submit`
/// (jobs) vs the direct library entry points must agree byte for byte,
/// and every config must agree with the scalar/LUT reference — the
/// bit-identity contract surfaced at the front door itself.
#[test]
fn engine_config_matrix_suite_and_gemm_byte_identical() {
    const SEED: u64 = 0xE96;
    for n in [64usize, 128] {
        let reference = {
            let eng = engine_cfg(CodecMode::Lut, Backend::Scalar);
            eng.submit(Job::Suite { n, seed: Some(SEED) }).unwrap().suite()
        };
        for backend in Backend::ALL {
            for mode in CodecMode::ALL {
                let eng = engine_cfg(mode, backend);
                // Submit path vs direct call path.
                let submitted = eng.submit(Job::Suite { n, seed: Some(SEED) }).unwrap().suite();
                let direct = run_suite(&eng, n, SEED).unwrap();
                assert_eq!(submitted.len(), direct.len());
                assert_eq!(submitted.len(), reference.len());
                for ((s, d), r) in submitted.iter().zip(&direct).zip(&reference) {
                    let tag = format!("{}/{} n={n} {mode:?}/{backend:?}", s.kernel, s.format);
                    assert_eq!((&s.kernel, &s.format, s.n), (&d.kernel, &d.format, d.n));
                    assert_eq!(s.rel_error.to_bits(), d.rel_error.to_bits(), "{tag}: submit≠direct");
                    assert_eq!(s.executed, d.executed, "{tag}: submit≠direct executed");
                    assert_eq!(s.counts, d.counts, "{tag}: submit≠direct counts");
                    // …and the whole matrix is pinned to the reference.
                    assert_eq!(s.rel_error.to_bits(), r.rel_error.to_bits(), "{tag}: vs reference");
                    assert_eq!(s.executed, r.executed, "{tag}: vs reference executed");
                    assert_eq!(s.dp_instructions, r.dp_instructions, "{tag}");
                    assert_eq!(s.convert_instructions, r.convert_instructions, "{tag}");
                    assert_eq!(s.counts, r.counts, "{tag}: vs reference counts");
                }

                // GEMM through both doors.
                let job = GemmJob { seed: Some(SEED), ..GemmJob::new(n, "t8") };
                let via_job = eng.submit(Job::Gemm(job)).unwrap().gemm();
                let via_call = gemm(&eng, n, "t8", SEED, 1.0).unwrap();
                assert_eq!(
                    via_job.rel_error.to_bits(),
                    via_call.rel_error.to_bits(),
                    "gemm n={n} {mode:?}/{backend:?}"
                );
                assert_eq!(via_job.executed, via_call.executed);
                assert_eq!(via_job.dp_instructions, via_call.dp_instructions);
            }
        }
    }
}

/// Direct `Machine` stepping on engine-built machines: the same small
/// FMA/convert program stepped by hand leaves bit-identical register
/// state in every engine config (the front door hands out machines whose
/// semantics do not depend on the config).
#[test]
fn direct_machine_stepping_matches_across_engine_configs() {
    let t8 = LaneType::Takum(8);
    let t16 = LaneType::Takum(16);
    let a: Vec<f64> = (0..64).map(|i| ((i % 9) as f64 - 4.0) * 0.75).collect();
    let b: Vec<f64> = (0..64).map(|i| ((i % 7) as f64 - 3.0) * 0.5).collect();
    let prog = [
        Instruction::new("VMULPT8", Operand::Vreg(2), vec![Operand::Vreg(0), Operand::Vreg(1)]),
        Instruction::new("VFMADD231PT8", Operand::Vreg(2), vec![Operand::Vreg(0), Operand::Vreg(1)]),
        Instruction::new("VDPPT8PT16", Operand::Vreg(3), vec![Operand::Vreg(0), Operand::Vreg(2)]),
        Instruction::new("VCVTPT162PT8", Operand::Vreg(4), vec![Operand::Vreg(3)]),
    ];
    let run = |eng: &Engine| {
        let mut m = eng.machine();
        m.load_f64(0, t8, &a);
        m.load_f64(1, t8, &b);
        m.load_f64(2, t8, &vec![0.0; 64]);
        m.load_f64(3, t16, &vec![0.0; 32]);
        for ins in &prog {
            m.step(ins).unwrap();
        }
        m
    };
    let reference = run(&engine_cfg(CodecMode::Lut, Backend::Scalar));
    for backend in Backend::ALL {
        for mode in CodecMode::ALL {
            let m = run(&engine_cfg(mode, backend));
            for reg in 0..5usize {
                assert_eq!(
                    reference.regs.v[reg], m.regs.v[reg],
                    "{mode:?}/{backend:?} v{reg}"
                );
            }
            assert_eq!(reference.executed, m.executed);
        }
    }
}

/// Builder validation at the public boundary: bad worker counts and
/// unknown backend/codec names fail `EngineConfig` with the messages the
/// CLI surfaces.
#[test]
fn builder_validation_messages() {
    let e = EngineConfig::new().workers(0).build().unwrap_err().to_string();
    assert!(e.contains("workers must be at least 1"), "{e:?}");
    assert!(e.contains("got 0"), "{e:?}");

    let e = EngineConfig::new().try_backend("cuda").unwrap_err().to_string();
    assert!(e.contains("unknown backend \"cuda\""), "{e:?}");
    for b in Backend::ALL {
        assert!(e.contains(b.name()), "{e:?} missing {}", b.name());
    }

    let e = EngineConfig::new().try_codec("table").unwrap_err().to_string();
    assert!(e.contains("unknown codec mode \"table\""), "{e:?}");
    for m in CodecMode::ALL {
        assert!(e.contains(m.name()), "{e:?} missing {}", m.name());
    }
}

/// The artifact front door: `Job::Artifact` serves the builtin graph set
/// through the engine-owned runtime, and unknown names error with the
/// available list.
#[test]
fn artifact_jobs_route_through_engine() {
    use takum_avx10::runtime::TensorF64;
    let eng = EngineConfig::new().build().unwrap();
    let names = eng.artifact_names().unwrap();
    assert!(names.iter().any(|n| n == "takum8_roundtrip"), "{names:?}");
    let out = eng
        .submit(Job::Artifact {
            name: "takum16_roundtrip".into(),
            inputs: vec![TensorF64::vec(vec![1.0, 2.5, -3.25, 1e30])],
        })
        .unwrap()
        .artifact();
    assert_eq!(out[0].len(), 4);
    // Round-trip through takum16 is exact on representable values.
    assert_eq!(out[0][0], 1.0);
    let err = eng
        .submit(Job::Artifact { name: "nope".into(), inputs: vec![] })
        .unwrap_err()
        .to_string();
    assert!(err.contains("not loaded"), "{err:?}");
}
