//! Unit tests for the graph compiler (`takum_avx10::opt`): per-rule
//! positive/negative pattern graphs, the embedding tables behind
//! `convert-widen`, fixpoint termination and the rule-budget fuse, the
//! static-verifier cleanliness of lowered programs, and the satellite
//! pin that lifting a convert-free takum kernel leaves the exact rule
//! set with zero convert work (the paper's fixpoint claim), while an
//! OFP8 cell hands it the whole storage↔compute convert tax.

use takum_avx10::engine::EngineConfig;
use takum_avx10::kernels::{Kernel, KernelSpec};
use takum_avx10::num::{BF16, E4M3, F16};
use takum_avx10::opt::{lower, run_lowered, Optimizer, RuleSet, CSE_RULE, RULE_BUDGET_DEFAULT};
use takum_avx10::sim::graph::BinOp;
use takum_avx10::sim::register::RegisterFile;
use takum_avx10::sim::{Graph, LaneType};

fn t8() -> LaneType {
    LaneType::Takum(8)
}

fn t16() -> LaneType {
    LaneType::Takum(16)
}

fn e4m3() -> LaneType {
    LaneType::Mini(E4M3)
}

fn f16() -> LaneType {
    LaneType::Mini(F16)
}

// ---------------------------------------------------------------------------
// Per-rule positive / negative pattern graphs
// ---------------------------------------------------------------------------

#[test]
fn convert_fold_erases_requantisation() {
    // Positive: Convert at the very type the operand is already
    // quantised at (idempotence).
    let mut g = Graph::new();
    let x = g.load(1, e4m3());
    let c = g.convert(x, e4m3());
    g.output(1, e4m3(), c);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("convert-fold"), 1);
    assert_eq!(g.len(), 1, "the redundant Convert must be dead-eliminated:\n{}", g.render());

    // Positive, constant arm: every lane of the constant round-trips
    // bit-exactly through the target type.
    let mut g = Graph::new();
    let one = g.splat(1.0);
    let c = g.convert(one, t8());
    g.output(2, t8(), c);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("convert-fold"), 1);

    // Negative: 0.1 is not representable at takum8, so the constant arm
    // must refuse (the quantisation would move the value).
    let mut g = Graph::new();
    let tenth = g.splat(0.1);
    let c = g.convert(tenth, t8());
    g.output(2, t8(), c);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("convert-fold"), 0);
    assert_eq!(report.rule("convert-widen"), 0);
    assert_eq!(g.len(), 2, "a value-changing Convert must survive:\n{}", g.render());
}

#[test]
fn convert_widen_erases_lossless_embeddings() {
    // Positive: the OFP8 cvt_in shape — storage e4m3 widened to the F16
    // compute type (every e4m3 value is exact in F16).
    let mut g = Graph::new();
    let x = g.load(1, e4m3());
    let c = g.convert(x, f16());
    g.output(1, f16(), c);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("convert-widen"), 1);
    assert_eq!(g.len(), 1, "{}", g.render());

    // Positive: takum prefix-code widening.
    let mut g = Graph::new();
    let x = g.load(3, t8());
    let c = g.convert(x, t16());
    g.output(3, t16(), c);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("convert-widen"), 1);

    // Negative: narrowing quantises — both convert rules must refuse.
    let mut g = Graph::new();
    let x = g.load(1, t16());
    let c = g.convert(x, t8());
    g.output(1, t8(), c);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("convert-widen"), 0);
    assert_eq!(report.rule("convert-fold"), 0);

    // Negative: F16 → BF16 is same-width but loses mantissa bits — not
    // an embedding even though the exponent range grows.
    let mut g = Graph::new();
    let x = g.load(1, f16());
    let c = g.convert(x, LaneType::Mini(BF16));
    g.output(1, LaneType::Mini(BF16), c);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("convert-widen"), 0);
}

/// The embedding table's takum arm, property-tested exhaustively (the
/// soundness note on `convert-widen` points here): every takum8 value —
/// NAR included — survives a takum16 round trip bit-for-bit at the f64
/// level, because shorter takum encodings are truncations of longer
/// ones.
#[test]
fn takum8_embeds_exactly_in_takum16() {
    for bits in 0u64..256 {
        let x = t8().decode(bits);
        let through16 = t16().decode(t16().encode(x));
        assert_eq!(
            x.to_bits(),
            through16.to_bits(),
            "takum8 bits {bits:#04x} (= {x}) moved under takum16 requantisation"
        );
    }
}

/// Same exhaustive check for the minifloat arm the OFP8 kernels lean
/// on: every e4m3 encoding is exact in F16.
#[test]
fn e4m3_embeds_exactly_in_f16() {
    for bits in 0u64..256 {
        let x = e4m3().decode(bits);
        let through = f16().decode(f16().encode(x));
        assert_eq!(
            x.to_bits(),
            through.to_bits(),
            "e4m3 bits {bits:#04x} (= {x}) moved under F16 requantisation"
        );
    }
}

#[test]
fn mul_one_aliases_either_side() {
    let mut g = Graph::new();
    let x = g.load(1, t16());
    let one = g.splat(1.0);
    let m = g.bin(BinOp::Mul, one, x);
    g.output(1, t16(), m);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("mul-one"), 1);
    assert_eq!(g.len(), 1, "{}", g.render());

    // Negative: an all-2.0 constant is not the multiplicative identity.
    let mut g = Graph::new();
    let x = g.load(1, t16());
    let two = g.splat(2.0);
    let m = g.bin(BinOp::Mul, x, two);
    g.output(1, t16(), m);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("mul-one"), 0);
    assert_eq!(g.len(), 3);
}

#[test]
fn add_zero_demands_the_signed_identity() {
    // Positive: x + (-0.0) and the symmetric -0.0 + x.
    let mut g = Graph::new();
    let x = g.load(1, f16());
    let z = g.splat(-0.0);
    let a = g.bin(BinOp::Add, z, x);
    g.output(1, f16(), a);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("add-zero"), 1);

    // Positive: x - (+0.0).
    let mut g = Graph::new();
    let x = g.load(1, f16());
    let z = g.splat(0.0);
    let s = g.bin(BinOp::Sub, x, z);
    g.output(1, f16(), s);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("add-zero"), 1);

    // Negative: x + (+0.0) flips the sign of a -0.0 lane — must not
    // fire.
    let mut g = Graph::new();
    let x = g.load(1, f16());
    let z = g.splat(0.0);
    let a = g.bin(BinOp::Add, x, z);
    g.output(1, f16(), a);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("add-zero"), 0, "x + (+0.0) is not an identity");

    // Negative: x - (-0.0) likewise (+0 - -0 = +0, but -0 - -0 = +0
    // flips).
    let mut g = Graph::new();
    let x = g.load(1, f16());
    let z = g.splat(-0.0);
    let s = g.bin(BinOp::Sub, x, z);
    g.output(1, f16(), s);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("add-zero"), 0);
}

#[test]
fn mul_zero_folds_only_under_the_finite_lane_proof() {
    // Positive: signed zeros come out of the fold exactly as the
    // evaluator would produce them (+0 · -3.5 = -0).
    let mut g = Graph::new();
    let z = g.splat(0.0);
    let c = g.splat(-3.5);
    let m = g.bin(BinOp::Mul, z, c);
    g.output(1, f16(), m);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("mul-zero"), 1);
    assert!(g.render().contains("-0"), "the folded constant must keep the -0 lanes:\n{}", g.render());

    // Negative: ±inf · 0 = NaN — a non-finite lane blocks the fold.
    let mut g = Graph::new();
    let z = g.splat(0.0);
    let c = g.splat(f64::INFINITY);
    let m = g.bin(BinOp::Mul, z, c);
    g.output(1, f16(), m);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("mul-zero"), 0);

    // Negative: a zero times a non-constant is not folded by this rule
    // (the runtime operand could be NaN or inf).
    let mut g = Graph::new();
    let z = g.splat(0.0);
    let x = g.load(1, f16());
    let m = g.bin(BinOp::Mul, z, x);
    g.output(1, f16(), m);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("mul-zero"), 0);
}

#[test]
fn dead_select_takes_the_statically_decided_arm() {
    let mut g = Graph::new();
    let a = g.load(1, t16());
    let b = g.load(2, t16());
    let s = g.select(u64::MAX, a, b);
    g.output(1, t16(), s);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("dead-select"), 1);
    assert_eq!(g.len(), 1, "only the taken arm survives:\n{}", g.render());

    let mut g = Graph::new();
    let a = g.load(1, t16());
    let b = g.load(2, t16());
    let s = g.select(0, a, b);
    g.output(1, t16(), s);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("dead-select"), 1);

    // Negative: a genuinely mixed mask keeps the Select.
    let mut g = Graph::new();
    let a = g.load(1, t16());
    let b = g.load(2, t16());
    let s = g.select(0x00FF_00FF, a, b);
    g.output(1, t16(), s);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("dead-select"), 0);
    assert_eq!(g.len(), 3);
}

#[test]
fn select_same_collapses_identical_arms_via_cse() {
    // The two arms are distinct nodes with identical structure: CSE
    // merges them first, which exposes select-same in the same
    // fixpoint.
    let mut g = Graph::new();
    let x = g.load(1, t16());
    let a = g.bin(BinOp::Add, x, x);
    let b = g.bin(BinOp::Add, x, x);
    let s = g.select(0x0F0F, a, b);
    g.output(1, t16(), s);
    let report = Optimizer::exact().run(&mut g);
    assert!(report.rule(CSE_RULE) >= 1, "CSE must merge the arms: {report:?}");
    assert_eq!(report.rule("select-same"), 1);
    assert_eq!(g.len(), 2, "{}", g.render());
}

#[test]
fn cse_merges_structural_duplicates_bit_exactly() {
    let mut g = Graph::new();
    let x = g.load(1, t16());
    let y = g.load(2, t16());
    let s1 = g.bin(BinOp::Add, x, y);
    let s2 = g.bin(BinOp::Add, x, y);
    g.output(1, t16(), s1);
    g.output(2, t16(), s2);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule(CSE_RULE), 1);
    assert_eq!(g.len(), 3, "{}", g.render());

    // Negative: two NaN constants with different payloads are not
    // structurally identical — CSE keys on bit patterns, not values.
    let mut g = Graph::new();
    let n1 = g.splat(f64::from_bits(0x7FF8_0000_0000_0001));
    let n2 = g.splat(f64::from_bits(0x7FF8_0000_0000_0002));
    g.output(1, f16(), n1);
    g.output(2, f16(), n2);
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule(CSE_RULE), 0, "distinct NaN payloads must not merge");
}

// ---------------------------------------------------------------------------
// Rule tiers: contractive rules only under `all()`
// ---------------------------------------------------------------------------

#[test]
fn contractive_rules_are_excluded_from_the_exact_tier() {
    let build = || {
        let mut g = Graph::new();
        let a = g.load(1, f16());
        let b = g.load(2, f16());
        let z = g.load(3, f16());
        let m = g.bin(BinOp::Mul, a, b);
        let s = g.bin(BinOp::Add, m, z);
        g.output(1, f16(), s);
        g
    };

    let mut g = build();
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("fma-fuse"), 0);
    assert_eq!(g.len(), 5, "the exact tier must leave Mul+Add alone:\n{}", g.render());

    let mut g = build();
    let report = Optimizer::all().run(&mut g);
    assert_eq!(report.rule("fma-fuse"), 1);
    assert!(g.render().contains("Fma"), "{}", g.render());
    assert_eq!(g.len(), 4, "the fused Mul goes dead:\n{}", g.render());
}

#[test]
fn dot_widen_folds_the_post_add_into_the_accumulator() {
    let build = || {
        let mut g = Graph::new();
        let a = g.load(1, f16());
        let b = g.load(2, f16());
        let w = g.load(3, f16());
        let zero = g.splat(0.0);
        let d = g.dot(a, b, zero);
        let s = g.bin(BinOp::Add, d, w);
        g.output(1, f16(), s);
        g
    };

    let mut g = build();
    let report = Optimizer::exact().run(&mut g);
    assert_eq!(report.rule("dot-widen"), 0);

    let mut g = build();
    let report = Optimizer::all().run(&mut g);
    assert_eq!(report.rule("dot-widen"), 1);
    assert_eq!(g.len(), 4, "the zero accumulator and old Dot go dead:\n{}", g.render());
}

#[test]
fn rule_set_tiers_and_names() {
    let exact = RuleSet::exact();
    let all = RuleSet::all();
    assert!(exact.rules().iter().all(|r| r.exact));
    assert!(all.rules().len() > exact.rules().len());
    // Names are the telemetry counter keys — CSE always included.
    assert!(exact.names().contains(&CSE_RULE));
    assert!(all.names().contains(&"fma-fuse"));
    assert!(!exact.names().contains(&"fma-fuse"));
}

// ---------------------------------------------------------------------------
// Fixpoint termination and the budget fuse
// ---------------------------------------------------------------------------

#[test]
fn fixpoint_is_reached_and_is_stable() {
    // A convert ladder interleaved with identities: several rules must
    // cooperate across iterations, and the default budget is nowhere
    // near.
    let mut g = Graph::new();
    let x = g.load(1, t8());
    let mut cur = x;
    for _ in 0..8 {
        cur = g.convert(cur, t16());
        let one = g.splat(1.0);
        cur = g.bin(BinOp::Mul, cur, one);
    }
    g.output(1, t16(), cur);
    let report = Optimizer::exact().run(&mut g);
    assert!(!report.budget_exhausted);
    assert!(report.total_applied() < RULE_BUDGET_DEFAULT);
    assert_eq!(report.rule("mul-one"), 8);
    // Every convert is the lossless t8 ⊆ t16 widening, so the whole
    // ladder collapses onto the bare load.
    assert_eq!(report.rule("convert-widen"), 8);
    assert_eq!(g.len(), 1, "{}", g.render());

    // Stability: a second run over the optimized graph is a no-op.
    let again = Optimizer::exact().run(&mut g);
    assert_eq!(again.total_applied(), 0, "fixpoint must be stable: {again:?}");
    assert_eq!(again.iterations, 1);
}

#[test]
fn budget_fuse_trips_at_an_iteration_boundary() {
    let build = || {
        let mut g = Graph::new();
        let x = g.load(1, t16());
        let mut cur = x;
        for _ in 0..16 {
            let one = g.splat(1.0);
            cur = g.bin(BinOp::Mul, cur, one);
        }
        g.output(1, t16(), cur);
        g
    };

    let mut g = build();
    let report = Optimizer::exact().with_budget(1).run(&mut g);
    assert!(report.budget_exhausted, "{report:?}");
    assert!(report.total_applied() >= 1);

    // The fuse trips between iterations, so the graph is left
    // consistent: a fresh default-budget run completes the fixpoint.
    let finish = Optimizer::exact().run(&mut g);
    assert!(!finish.budget_exhausted);
    assert_eq!(g.len(), 1, "{}", g.render());

    // A budget comfortably above the work needed never trips.
    let mut g = build();
    let report = Optimizer::exact().with_budget(RULE_BUDGET_DEFAULT).run(&mut g);
    assert!(!report.budget_exhausted);
}

// ---------------------------------------------------------------------------
// Lowered-program verifier cleanliness + kernel-cell pins
// ---------------------------------------------------------------------------

/// Every optimized kernel cell must lower to a program the static
/// verifier passes under `Deny`, and the lowered replay must reproduce
/// the direct machine's full register file bit-for-bit (the engine's
/// `--opt on` path relies on both).
#[test]
fn optimized_kernel_lowering_is_verifier_clean_and_bit_identical() {
    let eng = EngineConfig::new().build().expect("engine");
    let init = RegisterFile::default();
    for (kernel, format) in
        [(Kernel::Dot, "e4m3"), (Kernel::Dot, "t8"), (Kernel::Poly, "e5m2"), (Kernel::Softmax, "t16")]
    {
        let spec = KernelSpec { kernel, format, n: 64, seed: 7 };
        let run = spec.lower(&eng).expect("kernel run");
        let mut g = Graph::lift_with_loads(&run.program, &init, &run.loads)
            .unwrap_or_else(|e| panic!("{}/{format}: lift failed: {e}", kernel.name()));
        let report = Optimizer::exact().run(&mut g);
        assert!(!report.budget_exhausted);
        let low = lower(&g, &init)
            .unwrap_or_else(|e| panic!("{}/{format}: lowering failed: {e}", kernel.name()));
        let verdict = low.verify();
        assert!(
            verdict.passes_deny(),
            "{}/{format}: lowered program fails Verify::Deny:\n{}",
            kernel.name(),
            verdict.render_diagnostics()
        );
        let mut replay = eng.machine();
        run_lowered(&mut replay, &low)
            .unwrap_or_else(|e| panic!("{}/{format}: lowered replay failed: {e}", kernel.name()));
        for reg in 0..32 {
            assert_eq!(
                run.machine.regs.v[reg],
                replay.regs.v[reg],
                "{}/{format}: lowered replay diverges at v{reg}",
                kernel.name()
            );
        }
    }
}

/// Satellite pin: the lift-time fold removes the one redundant
/// requantising Convert the builder used to leave, so a convert-free
/// takum kernel reaches the optimizer *already at the convert fixpoint*
/// — the `PassStats` view shows zero convert-rule applications. The
/// OFP8 contrast cell hands the very same rule set its whole
/// storage↔compute convert chain.
#[test]
fn takum_kernels_lift_to_the_convert_fixpoint() {
    let eng = EngineConfig::new().build().expect("engine");
    let init = RegisterFile::default();

    for format in ["t8", "t16"] {
        for kernel in [Kernel::Dot, Kernel::Axpy, Kernel::Poly] {
            let spec = KernelSpec { kernel, format, n: 64, seed: 3 };
            let run = spec.lower(&eng).expect("kernel run");
            let mut g = Graph::lift_with_loads(&run.program, &init, &run.loads)
                .unwrap_or_else(|e| panic!("{}/{format}: lift failed: {e}", kernel.name()));
            let stats = Optimizer::exact().run(&mut g).pass_stats();
            assert_eq!(
                stats.converts_folded, 0,
                "{}/{format}: a takum cell must lift convert-clean, stats {stats:?}",
                kernel.name()
            );
        }
    }

    // Contrast: the e4m3 dot cell's cvt_in chain is entirely foldable —
    // the measurable half of the paper's convert-tax claim.
    let spec = KernelSpec { kernel: Kernel::Dot, format: "e4m3", n: 64, seed: 3 };
    let run = spec.lower(&eng).expect("kernel run");
    let mut g = Graph::lift_with_loads(&run.program, &init, &run.loads).expect("lift");
    let stats = Optimizer::exact().run(&mut g).pass_stats();
    assert!(
        stats.converts_folded > 0,
        "the e4m3 cell must hand the optimizer its convert tax, stats {stats:?}"
    );
}
