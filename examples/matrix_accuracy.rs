//! **End-to-end driver** (experiments E2–E4): the full Figure 2 pipeline
//! on the complete 1,401-matrix synthetic collection, at all three bit
//! widths, through the L3 coordinator — with the takum round-trips
//! executed by the **AOT-compiled Pallas kernels via PJRT** when the
//! artifacts are present (`make artifacts`), proving the three layers
//! compose on a real workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example matrix_accuracy
//! ```
//!
//! Output: the per-format CDF tables, ASCII CDF plots, throughput
//! metrics, and the headline §II comparison against the paper's numbers.
//! Recorded in EXPERIMENTS.md.

use takum_avx10::coordinator::{sweep, ConvertEngine, SweepConfig};
use takum_avx10::engine::EngineConfig;
use takum_avx10::harness::figure2::{render_ascii_plot, render_panel};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let count = if quick { 200 } else { 1401 };

    // One execution context for the whole run (worker pool + the
    // engine-owned PJRT artifact service).
    let eng = EngineConfig::from_env().build()?;

    // Try the full three-layer path first.
    let handle = match eng.pjrt() {
        Ok(h) => {
            println!("PJRT service up; takum conversions run through the AOT Pallas kernels");
            println!("artifacts: {:?}\n", h.names()?);
            Some(h)
        }
        Err(e) => {
            eprintln!("NOTE: no artifacts ({e:#}); falling back to native codecs\n");
            None
        }
    };

    let mut headline = Vec::new();
    for bits in [8u32, 16, 32] {
        let cfg = SweepConfig {
            spec: takum_avx10::matrix::generator::CollectionSpec {
                count,
                ..Default::default()
            },
            bits,
            convert: if handle.is_some() { ConvertEngine::Pjrt } else { ConvertEngine::Native },
            ..Default::default()
        };
        let (panel, metrics) = sweep(&cfg, &eng, handle.as_ref())?;
        println!("{}", render_panel(&panel));
        println!("{}", render_ascii_plot(&panel, 72, 18));
        println!("{}", metrics.render());
        for c in &panel.curves {
            headline.push((bits, c.format.clone(), c.fraction_below(0.999), c.fraction_exceeded()));
        }
    }

    // §II headline comparison (8-bit panel).
    println!("paper §II (8-bit): takum ≈ 90% below 100% error, posit ≈ 65%, E4M3/E5M2 ≈ 45–55%");
    println!("measured:");
    for (bits, f, below, inf) in &headline {
        if *bits == 8 {
            println!("  {f:<8} below-100%: {:.1}%   ∞-bucket: {:.1}%", below * 100.0, inf * 100.0);
        }
    }
    Ok(())
}
