//! Quickstart: a tour of the takum-avx10 public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use takum_avx10::num::{self, format_by_name, takum, takum_linear};

fn main() {
    // --- 1. Encode/decode any width ------------------------------------
    println!("== linear takum, any width ==");
    for n in [8u32, 12, 16, 32] {
        let bits = takum_linear::encode(std::f64::consts::PI, n);
        let back = takum_linear::decode(bits, n);
        println!(
            "π as takum{n:<2}  bits={bits:#010x}  value={back:.10}  rel.err={:.2e}",
            (back - std::f64::consts::PI).abs() / std::f64::consts::PI
        );
    }

    // --- 2. The format registry (all Figure 2 formats) -----------------
    println!("\n== registry ==");
    for name in ["takum8", "posit8", "e4m3", "e5m2", "float16", "bfloat16"] {
        let f = format_by_name(name).unwrap();
        println!(
            "{:<9} {:>2} bits  min={:.3e}  max={:.3e}  ({:.1} decades)",
            f.name(),
            f.bits(),
            f.min_positive(),
            f.max_finite(),
            f.dynamic_range_decades()
        );
    }

    // --- 3. Takum structural properties ---------------------------------
    println!("\n== takum structural properties ==");
    let x = 2.75f64;
    let b = takum_linear::encode(x, 16);
    let nb = takum_linear::encode(-x, 16);
    println!("negation is two's complement: enc({x})={b:#06x} enc({}) ={nb:#06x}", -x);
    assert_eq!(nb, (b.wrapping_neg()) & 0xFFFF);

    let small = takum_linear::encode(1.0, 16);
    let big = takum_linear::encode(1000.0, 16);
    println!(
        "comparison = signed-integer comparison: key(1.0)={} < key(1000.0)={}",
        takum_linear::order_key(small, 16),
        takum_linear::order_key(big, 16)
    );

    // saturation: takums never overflow to NaR
    assert_eq!(takum_linear::encode(1e300, 8), 0x7F);
    println!("saturation: 1e300 as takum8 = {:#04x} (max pos), never NaR", 0x7Fu8);

    // --- 4. Logarithmic takums: exact ℓ-domain multiplication ----------
    println!("\n== logarithmic takum ℓ-domain arithmetic ==");
    let a = takum::encode(3.0, 16);
    let (sa, la) = takum::log_fixed(a, 16).unwrap();
    let sq = takum::encode_from_log_fixed(sa, la * 2, 16);
    println!("3.0² via exact ℓ-doubling = {}", takum::decode(sq, 16));

    // --- 5. Double-double accumulation (the float128 stand-in) ---------
    println!("\n== double-double ==");
    let mut acc = num::Dd::ZERO;
    for _ in 0..1_000_000 {
        acc = acc.add_sq_f64(1e-8);
    }
    println!("Σ (1e-8)² ×1e6 = {:.6e} (f64 naive would lose precision)", acc.to_f64());

    println!("\nok");
}
