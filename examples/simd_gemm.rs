//! Experiment E11: quantised GEMM on the SIMD simulator — the proposed
//! `VDPPT8PT16` takum pipeline vs the AVX10.2 baselines, plus a
//! cross-check of the simulator against the AOT-compiled Pallas GEMM
//! kernel through PJRT.
//!
//! ```sh
//! cargo run --release --example simd_gemm [-- --n 64]
//! ```

use takum_avx10::engine::EngineConfig;
use takum_avx10::harness::gemm::{gemm_scaled, run_sim_gemm};
use takum_avx10::num::takum_linear;
use takum_avx10::runtime::TensorF64;
use takum_avx10::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = 64usize;

    // One execution context: backend/codec from the environment
    // (TAKUM_BACKEND/TAKUM_CODEC), and the engine-owned PJRT service for
    // the artifact cross-check below.
    let eng = EngineConfig::from_env().build()?;

    println!("=== well-scaled inputs (1 decade spread) ===");
    print!("{}", run_sim_gemm(&eng, n, "t8", 0xBEEF)?);

    println!("\n=== badly-scaled inputs (entries ~1e5, the FEM regime) ===");
    println!("{:<8} {:>12} {:>12}", "format", "rel. error", "instructions");
    for f in ["t8", "t16", "bf16", "f16", "e4m3", "e5m2"] {
        let r = gemm_scaled(&eng, n, f, 0xBEEF, 0.3, 1e5)?;
        println!("{:<8} {:>12.3e} {:>12}", r.format, r.rel_error, r.executed);
    }

    // Cross-check: the simulator's takum quantisation matches the Pallas
    // kernel artifact lane for lane.
    match eng.pjrt() {
        Ok(h) => {
            println!("\n=== PJRT cross-check (quant_gemm_t8 artifact, 128×128) ===");
            let dim = 128usize;
            let mut rng = Rng::new(0xF00D);
            let a: Vec<f64> = (0..dim * dim).map(|_| rng.log_normal(0.0, 1.0)).collect();
            let b: Vec<f64> = (0..dim * dim).map(|_| rng.log_normal(0.0, 1.0)).collect();
            let out = h.run_f64(
                "quant_gemm_t8",
                vec![
                    TensorF64::matrix(a.clone(), dim as i64, dim as i64),
                    TensorF64::matrix(b.clone(), dim as i64, dim as i64),
                ],
            )?;
            let c = &out[0];
            // every lane takum16-representable
            let all_t16 = c
                .iter()
                .all(|&y| takum_linear::decode(takum_linear::encode(y, 16), 16) == y);
            let mut c_ref = vec![0.0f64; dim * dim];
            for i in 0..dim {
                for k in 0..dim {
                    for j in 0..dim {
                        c_ref[i * dim + j] += a[i * dim + k] * b[k * dim + j];
                    }
                }
            }
            let (mut num, mut den) = (0.0, 0.0);
            for (x, y) in c.iter().zip(&c_ref) {
                num += (x - y) * (x - y);
                den += y * y;
            }
            println!(
                "kernel rel. error vs f64 GEMM: {:.3e}; all lanes takum16-representable: {all_t16}",
                (num / den).sqrt()
            );
        }
        Err(e) => eprintln!("\n(skipping PJRT cross-check: {e:#})"),
    }
    Ok(())
}
