//! Regenerate the paper's Tables I–V and the §IV evaluation summary
//! (experiments E5–E10).
//!
//! ```sh
//! cargo run --release --example isa_streamline
//! ```

use takum_avx10::isa::database::{groups, Category};
use takum_avx10::isa::report;
use takum_avx10::isa::transform::{map_instruction, Mapping};

fn main() {
    for cat in Category::ALL {
        println!("{}", report::render_category_table(cat));
    }
    println!("{}", report::render_summary());

    // A few concrete rename examples, mechanically derived:
    println!("example renames (method 2+3 of §III):");
    for (m, g) in [
        ("VADDNEPBF16", "F01"),
        ("VGETEXPPH", "F03"),
        ("VCVTPH2UW", "F07"),
        ("VCVTBIASPH2BF8", "F07"),
        ("VPMOVUSQB", "I08"),
        ("KORTESTW", "M01"),
        ("VPGATHERDQ", "B01"),
    ] {
        match map_instruction(m, g) {
            Mapping::To(t) => println!("  {m:<16} → {t}"),
            Mapping::Removed(r) => println!("  {m:<16} → (removed: {})", &r[..40.min(r.len())]),
        }
    }

    // Totals per legacy group for reference.
    println!("\nper-group sizes:");
    for g in groups() {
        println!(
            "  {}  {:>3} instructions  ({})",
            g.spec.id,
            g.avx_instructions.len(),
            g.spec.note
        );
    }
}
