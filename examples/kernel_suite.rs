//! Walkthrough of the kernel-builder subsystem: run the whole workload
//! suite on both ISAs, show the comparison table, then zoom into one
//! kernel's emitted program to see where the OFP8 conversion tax comes
//! from.
//!
//! ```text
//! cargo run --example kernel_suite
//! ```

use takum_avx10::coordinator::KernelSweep;
use takum_avx10::engine::{EngineConfig, Job};
use takum_avx10::kernels::{render, Kernel, KernelSpec, Pipeline};

fn main() -> anyhow::Result<()> {
    // The single front door: one engine (backend/codec/workers from env
    // or defaults) runs everything below.
    let eng = EngineConfig::from_env().build()?;

    // 1. The full suite — every kernel × format × two sizes, fanned out
    //    across the engine's worker pool. Results are deterministic
    //    regardless of the worker count.
    let spec = KernelSweep { sizes: vec![64, 128], ..Default::default() };
    let (results, metrics) = eng.submit(Job::Sweep(spec))?.sweep();
    print!("{}", render(&results));
    eprint!("{}", metrics.render());

    // 2. One lowering under the microscope: softmax in takum8 vs OFP8
    //    E4M3. Same builder, same roles — the histogram shows the OFP8
    //    program spending a third of its instructions on VCVT converts
    //    while the takum program spends none.
    for format in ["t8", "e4m3"] {
        let pipe = Pipeline::for_format(format)?;
        let spec = KernelSpec { kernel: Kernel::Softmax, format, n: 64, seed: 42 };
        let r = spec.run(&eng)?;
        println!(
            "\nsoftmax n=64 in {format} ({}): rel.err={:.3e}, {} instructions",
            pipe.isa.name(),
            r.rel_error,
            r.executed
        );
        for (mnemonic, count) in &r.counts {
            println!("  {mnemonic:<16} {count}");
        }
    }
    Ok(())
}
