//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The repository builds fully offline (no crates.io registry), so the
//! subset of `anyhow` the codebase uses is vendored here: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the
//! [`Context`] extension trait. Semantics mirror upstream where it
//! matters to callers:
//!
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole context chain separated by `": "`.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], preserving its source chain.
//! * `.context(..)` / `.with_context(..)` wrap an error with an outer
//!   message, exactly like upstream.

use std::fmt;

/// Error type: an outermost message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from a pre-formatted message (used by [`anyhow!`]).
    pub fn from_msg(msg: String) -> Error {
        Error { msg, source: None }
    }

    /// Construct from anything displayable (upstream `Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error::from_msg(m.to_string())
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, e) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {}", c.msg)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        fn build(e: &dyn std::error::Error) -> Error {
            Error {
                msg: e.to_string(),
                source: e.source().map(|s| Box::new(build(s))),
            }
        }
        build(&e)
    }
}

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let base: Error = e.into();
            base.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let base: Error = e.into();
            base.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::from_msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::from_msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::from_msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::from_msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: `", ::std::stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = anyhow!("inner {}", 7);
        let e = e.context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_on_std_and_anyhow_results() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: missing thing");

        let r: Result<()> = Err(anyhow!("base"));
        let e = r.with_context(|| format!("wrapped {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "wrapped 1: base");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(200).unwrap_err().to_string().contains("too big"));
    }
}
