#!/usr/bin/env python3
"""Diff two sets of BENCH_*.json perf-trajectory files (schema v2 or v3,
as emitted by the Rust benches' hand-rolled JSON writer) and report
median wall-time regressions plus — for v3 files that embed a telemetry
snapshot — cache-hit-rate and convert-count drift.

Usage:
    bench_trend.py BASELINE_DIR CURRENT_DIR [--threshold PCT]

Measurements are keyed on (group, name) — per the schema, rows that pin
a non-default engine config carry it in the measurement *name* (the
`[lut]`/`[arith]`/`[scalar|vector|graph]`/`[verify=…]`/`[telemetry=…]`
suffixes), so the key is stable across runs even though the file-level
`engine_config` tag varies by CI matrix leg.

Rows are never compared across different resolved SIMD tiers: when the
file-level `engine_config` tags disagree on their `simd=<tier>` token
(runner generation changed, forced-tier leg repointed), the file prints
a tier-changed notice and is skipped — cross-tier timing deltas are
by-design, not regressions.

Missing, corrupt, or unsupported-schema baselines are reported and
skipped — a first run (no baseline yet) must never stack-trace. The
telemetry diff is purely informational: a plan/shadow hit-rate drop of
more than 5 points is flagged in the summary but never affects the exit
code.

Emits a GitHub-flavoured-markdown summary on stdout (CI appends it to
$GITHUB_STEP_SUMMARY). Exits 2 when any measurement regressed by more
than the threshold, 0 otherwise; shared-runner timing is noisy, so
callers treat this as a visibility signal, not a gate (the CI step is
continue-on-error).
"""

import argparse
import json
import sys
from pathlib import Path

SUPPORTED_SCHEMAS = (2, 3)

# Telemetry flagging threshold: hit-rate drops beyond this many
# percentage points are called out (informational only).
HIT_RATE_DROP_POINTS = 5.0


def load(path):
    """Parse one bench JSON file into ({(group, name): median_ns}, telemetry, simd).

    `telemetry` is the embedded snapshot object for schema-v3 files that
    attached one, else None (schema v2 has no such key). `simd` is the
    resolved SIMD tier extracted from the file-level `engine_config` tag
    (the `simd=<tier>` token), or None for pre-tier files.
    """
    doc = json.loads(Path(path).read_text())
    schema = doc.get("schema_version")
    if schema not in SUPPORTED_SCHEMAS:
        raise ValueError(
            f"unsupported schema_version {schema!r} (supported: {list(SUPPORTED_SCHEMAS)})"
        )
    rows = {}
    for r in doc.get("results", []):
        rows[(r.get("group", ""), r["name"])] = float(r["median_ns"])
    return rows, doc.get("telemetry"), engine_simd(doc.get("engine_config"))


def engine_simd(tag):
    """The `simd=<tier>` token of an `engine_config` tag, or None.

    Pre-tier artifacts (and v2 files) have no such token; they compare
    freely, as before the tier axis existed.
    """
    if not isinstance(tag, str):
        return None
    for token in tag.split(";"):
        if token.startswith("simd="):
            return token[len("simd="):]
    return None


def load_or_none(path, label):
    """`load`, but degrade any failure to a skip message (no stack trace)."""
    try:
        return load(path)
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"\n`{path.name}`: unreadable {label} ({e}) — skipped")
        return None


def hit_rate(counters, kind):
    """Hit rate in percent for `plan`/`shadow`, or None before any lookup."""
    hits = counters.get(f"{kind}_hits", 0)
    total = hits + counters.get(f"{kind}_misses", 0)
    return hits / total * 100.0 if total else None


def telemetry_diff(base_telem, cur_telem):
    """Print hit-rate / convert drift between two embedded snapshots.

    Rows are buffered and the section header is emitted only when at
    least one row survives — two snapshots with empty or disjoint
    counters produce no output at all, rather than a dangling header.

    Returns the list of flagged drift strings (informational — the
    caller never turns these into a failing exit code).
    """
    if not isinstance(base_telem, dict) or not isinstance(cur_telem, dict):
        return []
    base_c = base_telem.get("counters", {})
    cur_c = cur_telem.get("counters", {})
    flagged = []
    rows = []
    for kind, label in (("plan", "plan-cache"), ("shadow", "decoded-shadow")):
        b, c = hit_rate(base_c, kind), hit_rate(cur_c, kind)
        if b is None or c is None:
            continue
        note = ""
        if b - c > HIT_RATE_DROP_POINTS:
            note = f"  ⚠ dropped >{HIT_RATE_DROP_POINTS:.0f} points"
            flagged.append(f"{label} hit rate {b:.1f}% → {c:.1f}%")
        rows.append(f"    {label} hit rate: {b:.1f}% → {c:.1f}%{note}")
    for key in ("converts", "dots", "executed", "opt.lowered_programs", "opt.nodes_removed"):
        b, c = base_c.get(key), cur_c.get(key)
        if b is None or c is None:
            continue
        note = " (changed)" if b != c else ""
        rows.append(f"    {key}: {b} → {c}{note}")
    # Per-rewrite-rule application counters from the graph-compiler
    # snapshot (`opt.rule.<name>.applied`, carried as the `opt_rules`
    # map). A shifted count is informational — it usually tracks an
    # intentional rule-table or kernel-lowering change — but a rule
    # falling to zero that used to fire is worth a look.
    base_r = base_telem.get("opt_rules", {}) or {}
    cur_r = cur_telem.get("opt_rules", {}) or {}
    if isinstance(base_r, dict) and isinstance(cur_r, dict):
        for rule in sorted(set(base_r) | set(cur_r)):
            b, c = base_r.get(rule, 0), cur_r.get(rule, 0)
            if b == 0 and c == 0:
                continue
            note = " (changed)" if b != c else ""
            rows.append(f"    opt.rule.{rule}.applied: {b} → {c}{note}")
    if rows:
        print("\n  telemetry drift (informational, never gates):")
        for row in rows:
            print(row)
    return flagged


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="directory holding the previous run's BENCH_*.json")
    ap.add_argument("current", help="directory holding this run's BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=10.0,
        help="flag regressions above this percentage of median time (default 10)",
    )
    args = ap.parse_args()

    base_dir = Path(args.baseline)
    cur_dir = Path(args.current)
    compared = 0
    regressions = []
    telemetry_flags = []

    print(f"### Bench trend vs previous run (threshold +{args.threshold:.0f}%)")
    if not base_dir.is_dir():
        print(f"\nBaseline directory `{base_dir}` missing — first run, nothing to compare.")
        return 0
    for cur_file in sorted(cur_dir.glob("BENCH_*.json")):
        base_file = base_dir / cur_file.name
        if not base_file.exists():
            print(f"\n`{cur_file.name}`: no baseline file — skipped")
            continue
        base = load_or_none(base_file, "baseline")
        if base is None:
            continue
        cur = load_or_none(cur_file, "current run")
        if cur is None:
            continue
        base_rows, base_telem, base_simd = base
        cur_rows, cur_telem, cur_simd = cur
        if base_simd != cur_simd and base_simd is not None and cur_simd is not None:
            # A different SIMD tier served the two runs (new CI runner
            # generation, forced-tier leg renamed, …). Timings across
            # tiers differ by design — diffing them reports phantom
            # regressions, so this file is a notice, never a comparison.
            print(
                f"\n`{cur_file.name}`: SIMD tier changed "
                f"({base_simd} → {cur_simd}) — timings not comparable "
                "across tiers, file skipped"
            )
            continue
        flagged = []
        for key in sorted(cur_rows):
            if key not in base_rows or base_rows[key] <= 0.0:
                continue
            compared += 1
            delta = (cur_rows[key] - base_rows[key]) / base_rows[key] * 100.0
            if delta > args.threshold:
                flagged.append((key, base_rows[key], cur_rows[key], delta))
        print(
            f"\n`{cur_file.name}`: {len(cur_rows)} measurements, "
            f"{len(flagged)} regressed beyond threshold"
        )
        if flagged:
            print("\n| group | name | baseline | current | delta |")
            print("|---|---|---|---|---|")
            for (group, name), b, c, delta in flagged:
                print(f"| {group} | {name} | {b:,.0f} ns | {c:,.0f} ns | +{delta:.1f}% |")
        regressions.extend(flagged)
        telemetry_flags.extend(telemetry_diff(base_telem, cur_telem))

    if telemetry_flags:
        print(
            f"\n{len(telemetry_flags)} telemetry hit-rate drop(s) beyond "
            f"{HIT_RATE_DROP_POINTS:.0f} points (informational — investigate cache "
            "behaviour, but this never fails the step):"
        )
        for f in telemetry_flags:
            print(f"- {f}")

    if compared == 0:
        print("\nNo overlapping measurements — nothing compared.")
        return 0
    if not regressions:
        print(f"\nAll {compared} overlapping measurements within threshold.")
        return 0
    print(
        f"\n{len(regressions)} of {compared} measurements regressed "
        f"beyond +{args.threshold:.0f}% (noise on shared runners is common; "
        "compare across several runs before acting)."
    )
    return 2


if __name__ == "__main__":
    sys.exit(main())
