#!/usr/bin/env python3
"""Diff two sets of BENCH_*.json perf-trajectory files (schema v2, as
emitted by the Rust benches' hand-rolled JSON writer) and report median
wall-time regressions.

Usage:
    bench_trend.py BASELINE_DIR CURRENT_DIR [--threshold PCT]

Measurements are keyed on (group, name) — per the schema, rows that pin
a non-default engine config carry it in the measurement *name* (the
`[lut]`/`[arith]`/`[scalar|vector|graph]`/`[verify=…]` suffixes), so the
key is stable across runs even though the file-level `engine_config` tag
varies by CI matrix leg.

Emits a GitHub-flavoured-markdown summary on stdout (CI appends it to
$GITHUB_STEP_SUMMARY). Exits 2 when any measurement regressed by more
than the threshold, 0 otherwise; shared-runner timing is noisy, so
callers treat this as a visibility signal, not a gate (the CI step is
continue-on-error).
"""

import argparse
import json
import sys
from pathlib import Path


def load(path):
    """Parse one bench JSON file into {(group, name): median_ns}."""
    doc = json.loads(Path(path).read_text())
    rows = {}
    for r in doc.get("results", []):
        rows[(r.get("group", ""), r["name"])] = float(r["median_ns"])
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="directory holding the previous run's BENCH_*.json")
    ap.add_argument("current", help="directory holding this run's BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=10.0,
        help="flag regressions above this percentage of median time (default 10)",
    )
    args = ap.parse_args()

    base_dir = Path(args.baseline)
    cur_dir = Path(args.current)
    compared = 0
    regressions = []

    print(f"### Bench trend vs previous run (threshold +{args.threshold:.0f}%)")
    for cur_file in sorted(cur_dir.glob("BENCH_*.json")):
        base_file = base_dir / cur_file.name
        if not base_file.exists():
            print(f"\n`{cur_file.name}`: no baseline file — skipped")
            continue
        base = load(base_file)
        cur = load(cur_file)
        flagged = []
        for key in sorted(cur):
            if key not in base or base[key] <= 0.0:
                continue
            compared += 1
            delta = (cur[key] - base[key]) / base[key] * 100.0
            if delta > args.threshold:
                flagged.append((key, base[key], cur[key], delta))
        print(
            f"\n`{cur_file.name}`: {len(cur)} measurements, "
            f"{len(flagged)} regressed beyond threshold"
        )
        if flagged:
            print("\n| group | name | baseline | current | delta |")
            print("|---|---|---|---|---|")
            for (group, name), b, c, delta in flagged:
                print(f"| {group} | {name} | {b:,.0f} ns | {c:,.0f} ns | +{delta:.1f}% |")
        regressions.extend(flagged)

    if compared == 0:
        print("\nNo overlapping measurements — nothing compared.")
        return 0
    if not regressions:
        print(f"\nAll {compared} overlapping measurements within threshold.")
        return 0
    print(
        f"\n{len(regressions)} of {compared} measurements regressed "
        f"beyond +{args.threshold:.0f}% (noise on shared runners is common; "
        "compare across several runs before acting)."
    )
    return 2


if __name__ == "__main__":
    sys.exit(main())
