"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

The kernels run under interpret=True (the same lowering the AOT artifacts
use), so agreement here transfers directly to what the rust runtime
executes."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import quant_gemm as qg
from compile.kernels import ref, takum_codec


def _batch(values):
    """Pad a value list to one kernel block."""
    x = np.zeros(takum_codec.BLOCK, dtype=np.float64)
    x[: len(values)] = values
    return jnp.asarray(x)


SPECIALS = [0.0, 1.0, -1.0, 1.5, -0.75, 2.0**100, -(2.0**-100), 448.0, 1e300, -1e-300,
            float("inf"), float("-inf"), float("nan"), 3.75, -123.25, 2.0**-1074]


@pytest.mark.parametrize("n", [8, 16, 32])
def test_roundtrip_kernel_matches_ref(n):
    rng = np.random.default_rng(42)
    vals = np.concatenate(
        [
            np.array(SPECIALS),
            rng.lognormal(0, 30, 400) * rng.choice([-1, 1], 400),
            rng.normal(0, 1, 400),
        ]
    )
    x = _batch(list(vals))
    got = np.asarray(takum_codec.takum_roundtrip(x, n))
    want = np.asarray(ref.takum_roundtrip(x, n))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [8, 16, 32])
def test_encode_decode_kernels_match_ref(n):
    rng = np.random.default_rng(7)
    x = _batch(list(rng.lognormal(0, 20, 900) * rng.choice([-1, 1], 900)))
    got_bits = np.asarray(takum_codec.takum_encode(x, n))
    want_bits = np.asarray(ref.takum_encode(x, n))
    np.testing.assert_array_equal(got_bits, want_bits)
    got_vals = np.asarray(takum_codec.takum_decode(jnp.asarray(got_bits), n))
    want_vals = np.asarray(ref.takum_decode(jnp.asarray(want_bits), n))
    np.testing.assert_array_equal(got_vals, want_vals)


def test_multi_block_grid():
    # 4 blocks: the grid/BlockSpec tiling must not permute values.
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 100, 4 * takum_codec.BLOCK))
    got = np.asarray(takum_codec.takum_roundtrip(x, 16))
    want = np.asarray(ref.takum_roundtrip(x, 16))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    n=st.sampled_from([8, 16, 32]),
    scale=st.floats(min_value=-60, max_value=60),
)
def test_prop_kernel_equals_ref_random_batches(seed, n, scale):
    rng = np.random.default_rng(seed)
    x = _batch(list(rng.normal(0, 1, 1000) * 10.0**scale))
    got = np.asarray(takum_codec.takum_roundtrip(x, n))
    want = np.asarray(ref.takum_roundtrip(x, n))
    np.testing.assert_array_equal(got, want)


def test_gemm_kernel_matches_tiled_reference():
    rng = np.random.default_rng(11)
    m = qg.TILE
    a = jnp.asarray(rng.lognormal(0, 1, (m, 2 * m)) * rng.choice([-1, 1], (m, 2 * m)))
    b = jnp.asarray(rng.lognormal(0, 1, (2 * m, m)) * rng.choice([-1, 1], (2 * m, m)))
    got = np.asarray(qg.quant_gemm(a, b, 8, 16))
    want = np.asarray(ref.quant_gemm(a, b, 8, 16, k_chunk=qg.TILE))
    np.testing.assert_array_equal(got, want)


def test_gemm_kernel_accuracy_sane():
    rng = np.random.default_rng(13)
    m = qg.TILE
    a = jnp.asarray(rng.lognormal(0, 1, (m, m)))
    b = jnp.asarray(rng.lognormal(0, 1, (m, m)))
    got = np.asarray(qg.quant_gemm(a, b, 8, 16))
    exact = np.asarray(a) @ np.asarray(b)
    rel = np.linalg.norm(got - exact) / np.linalg.norm(exact)
    assert 0 < rel < 0.2, rel
