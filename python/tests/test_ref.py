"""Oracle self-tests: the pure-jnp codec against first-principles takum
properties (mirroring the rust unit tests, so L1 and L3 provably agree on
the same spec)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref


def enc1(x, n):
    return int(ref.takum_encode(jnp.array([x], jnp.float64), n)[0])


def dec1(b, n):
    return float(ref.takum_decode(jnp.array([b], jnp.uint64), n)[0])


@pytest.mark.parametrize("n", [8, 12, 16, 32, 48])
def test_zero_and_nar(n):
    assert enc1(0.0, n) == 0
    assert dec1(0, n) == 0.0
    assert enc1(float("nan"), n) == 1 << (n - 1)
    assert enc1(float("inf"), n) == 1 << (n - 1)
    assert np.isnan(dec1(1 << (n - 1), n))


@pytest.mark.parametrize("n", [8, 12, 16, 32])
def test_one_and_known_values(n):
    assert enc1(1.0, n) == 0b01 << (n - 2)
    assert dec1(0b01 << (n - 2), n) == 1.0


def test_known_12bit_patterns():
    assert enc1(1.5, 12) == 0b0_1_000_1000000
    assert enc1(0.75, 12) == 0b0_0_111_1000000
    assert dec1(0b0_1_000_1000000, 12) == 1.5


@pytest.mark.parametrize("n", [8, 12, 16, 32])
def test_saturation_not_nar_not_zero(n):
    assert enc1(1e300, n) == (1 << (n - 1)) - 1
    assert enc1(1e-300, n) == 1
    assert enc1(-1e300, n) == (1 << (n - 1)) + 1
    assert enc1(-1e-300, n) == (1 << n) - 1


def test_negation_is_twos_complement_exhaustive_8bit():
    bits = jnp.arange(256, dtype=jnp.uint64)
    vals = ref.takum_decode(bits, 8)
    neg_bits = (~bits + jnp.uint64(1)) & jnp.uint64(0xFF)
    neg_vals = ref.takum_decode(neg_bits, 8)
    v = np.asarray(vals)
    nv = np.asarray(neg_vals)
    mask = ~np.isnan(v)
    np.testing.assert_array_equal(nv[mask], -v[mask])


def test_roundtrip_idempotent_exhaustive_16bit():
    bits = jnp.arange(1 << 16, dtype=jnp.uint64)
    nar = 1 << 15
    vals = ref.takum_decode(bits, 16)
    back = ref.takum_encode(jnp.where(jnp.isnan(vals), 0.0, vals), 16)
    b = np.asarray(bits)
    bk = np.asarray(back)
    mask = b != nar
    np.testing.assert_array_equal(bk[mask], b[mask])


def test_monotone_exhaustive_8bit():
    # signed-int order of encodings == value order
    ks = np.arange(-127, 128)
    vals = np.asarray(ref.takum_decode(jnp.array(ks % 256, jnp.uint64), 8))
    assert np.all(np.diff(vals) > 0)


@settings(max_examples=300, deadline=None)
@given(
    x=st.floats(
        allow_nan=False,
        allow_infinity=False,
        min_value=-1e60,
        max_value=1e60,
    ),
    n=st.sampled_from([8, 12, 16, 24, 32, 40]),
)
def test_prop_decode_encode_idempotent(x, n):
    b = enc1(x, n)
    v = dec1(b, n)
    if np.isnan(v):
        return
    assert enc1(v, n) == b


@settings(max_examples=300, deadline=None)
@given(
    x=st.floats(allow_nan=False, allow_infinity=False, min_value=1e-30, max_value=1e30),
    n=st.sampled_from([8, 16, 32]),
)
def test_prop_rounds_to_bracketing_neighbour(x, n):
    b = enc1(x, n)
    v = dec1(b, n)
    up = dec1((b + 1) & ((1 << n) - 1), n)
    dn = dec1((b - 1) & ((1 << n) - 1), n)
    assert dn <= x <= up or v == x


@settings(max_examples=200, deadline=None)
@given(
    x=st.floats(allow_nan=False, allow_infinity=False, min_value=-1e30, max_value=1e30),
)
def test_prop_wider_is_more_accurate(x):
    if x == 0:
        return
    errs = []
    for n in (8, 16, 32):
        v = dec1(enc1(x, n), n)
        errs.append(abs(v - x) / abs(x))
    assert errs[0] >= errs[1] >= errs[2]


def test_quant_gemm_reference_shapes_and_exactness():
    # Powers of two are exact in every takum width: a power-of-two GEMM
    # with small exact accumulations must be exact end to end.
    a = jnp.full((4, 4), 2.0, jnp.float64)
    b = jnp.eye(4, dtype=jnp.float64) * 0.5
    c = ref.quant_gemm(a, b, 8, 16, k_chunk=2)
    np.testing.assert_array_equal(np.asarray(c), np.full((4, 4), 1.0))
