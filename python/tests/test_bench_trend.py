"""Live tests for python/bench_trend.py, the perf-trajectory differ.

Dependency-free by design (unittest + subprocess + tempfile only): the
script itself runs on bare python3 in CI, and so must its tests — no
pytest, no jax, no fixtures beyond temp directories.

Covers the output contract CI depends on:
- empty / counter-less telemetry snapshots emit NO drift header (the
  header appears only when at least one drift row exists);
- populated snapshots emit the header plus rows;
- a regression beyond the threshold exits 2, within-threshold exits 0;
- a missing baseline directory is a clean first-run skip (exit 0);
- a serve artifact's extra top-level `serve` object is ignored.
"""

import json
import subprocess
import sys
import tempfile
import unittest
from pathlib import Path

SCRIPT = Path(__file__).resolve().parents[1] / "bench_trend.py"

TAG = "backend=scalar;codec=lut;workers=2;verify=off;trace=none;simd=scalar"


def artifact(rows, telemetry=None, extra=None):
    """A minimal schema-v3 bench JSON document: rows is {name: median_ns}."""
    doc = {
        "schema_version": 3,
        "bench": "unit",
        "engine_config": TAG,
        "telemetry": telemetry,
        "results": [
            {
                "group": "g",
                "name": name,
                "median_ns": float(median),
                "mean_ns": float(median),
                "stddev_ns": 0.0,
                "iters": 1,
                "elements": None,
                "throughput_elem_per_s": None,
            }
            for name, median in rows.items()
        ],
    }
    if extra:
        doc.update(extra)
    return doc


def run_trend(base_docs, cur_docs, threshold=10):
    """Write the given {filename: doc} trees and run the differ on them."""
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        base = td / "base"
        cur = td / "cur"
        cur.mkdir()
        if base_docs is not None:
            base.mkdir()
            for name, doc in base_docs.items():
                (base / name).write_text(json.dumps(doc))
        for name, doc in cur_docs.items():
            (cur / name).write_text(json.dumps(doc))
        return subprocess.run(
            [sys.executable, str(SCRIPT), str(base), str(cur), "--threshold", str(threshold)],
            capture_output=True,
            text=True,
            check=False,
        )


class TelemetryDriftHeader(unittest.TestCase):
    def test_empty_counters_emit_no_drift_header(self):
        """Two snapshots whose counters produce zero drift rows must not
        print the dangling 'telemetry drift' header."""
        for counters in ({}, {"unrelated": 1}):
            telem = {"schema": 1, "counters": counters}
            p = run_trend(
                {"BENCH_x.json": artifact({"a": 100}, telemetry=telem)},
                {"BENCH_x.json": artifact({"a": 100}, telemetry=telem)},
            )
            self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
            self.assertNotIn("telemetry drift", p.stdout, p.stdout)

    def test_null_telemetry_emits_no_drift_header(self):
        p = run_trend(
            {"BENCH_x.json": artifact({"a": 100})},
            {"BENCH_x.json": artifact({"a": 100})},
        )
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertNotIn("telemetry drift", p.stdout, p.stdout)

    def test_populated_counters_emit_header_and_rows(self):
        base_t = {
            "schema": 1,
            "counters": {"plan_hits": 90, "plan_misses": 10, "converts": 5},
        }
        cur_t = {
            "schema": 1,
            "counters": {"plan_hits": 50, "plan_misses": 50, "converts": 7},
        }
        p = run_trend(
            {"BENCH_x.json": artifact({"a": 100}, telemetry=base_t)},
            {"BENCH_x.json": artifact({"a": 100}, telemetry=cur_t)},
        )
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("telemetry drift", p.stdout)
        self.assertIn("plan-cache hit rate: 90.0% → 50.0%", p.stdout)
        self.assertIn("converts: 5 → 7 (changed)", p.stdout)
        # 90 → 50 is a >5-point drop: flagged in the summary.
        self.assertIn("hit-rate drop", p.stdout)


class RegressionGate(unittest.TestCase):
    def test_regression_beyond_threshold_exits_2(self):
        p = run_trend(
            {"BENCH_x.json": artifact({"a": 100})},
            {"BENCH_x.json": artifact({"a": 150})},
        )
        self.assertEqual(p.returncode, 2, p.stdout + p.stderr)
        self.assertIn("regressed", p.stdout)

    def test_within_threshold_exits_0(self):
        p = run_trend(
            {"BENCH_x.json": artifact({"a": 100})},
            {"BENCH_x.json": artifact({"a": 105})},
        )
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)

    def test_missing_baseline_dir_is_first_run_skip(self):
        p = run_trend(None, {"BENCH_x.json": artifact({"a": 100})})
        self.assertEqual(p.returncode, 0, p.stdout + p.stderr)
        self.assertIn("first run", p.stdout)


class ServeArtifact(unittest.TestCase):
    def test_extra_serve_object_is_ignored(self):
        """BENCH_serve.json carries a deterministic top-level `serve`
        object; the differ must diff the timing rows and ignore it."""
        serve = {
            "serve": {
                "requests": 1000,
                "completed": 1000,
                "shed": 0,
                "errors": 0,
                "coalesced": 400,
                "batches": 60,
                "batch_size_histogram": {"16": 60},
            }
        }
        p = run_trend(
            {"BENCH_serve.json": artifact({"e2e latency [p50]": 1000}, extra=serve)},
            {"BENCH_serve.json": artifact({"e2e latency [p50]": 2000}, extra=serve)},
        )
        self.assertEqual(p.returncode, 2, p.stdout + p.stderr)
        self.assertIn("e2e latency [p50]", p.stdout)


if __name__ == "__main__":
    unittest.main()
