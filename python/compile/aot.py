"""AOT lowering: JAX -> HLO *text* artifacts for the rust PJRT runtime.

HLO text (not a serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
so the rust side unwraps a tuple uniformly.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (invoked by
``make artifacts``). Python never runs after this point.
"""

import argparse
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from .model import ARTIFACTS  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: pathlib.Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, (fn, example_args) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
