"""L2: the JAX computations that get AOT-compiled for the rust runtime.

Two computation families, both calling the L1 Pallas kernels:

* ``takum_roundtrip_fn(n)`` — the Figure 2 conversion hot path: round-trip
  a fixed-size batch of f64 values through the n-bit linear takum codec.
  The rust coordinator streams matrix values through this executable in
  `--engine pjrt` mode.
* ``quant_gemm_fn()`` — the `VDPPT8PT16` widening-dot-product GEMM on a
  fixed 128×128 problem (takum8 inputs, takum16 accumulators).

Everything is shaped statically (PJRT AOT requires it); the rust side pads
its batches. f64 throughout: the conversion-error measurement needs more
precision than f32 carries (takum32 round-trip errors are ~1e-11).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import quant_gemm as qg  # noqa: E402
from .kernels import takum_codec  # noqa: E402

# Batch size of the round-trip executable (must match
# `SweepConfig::pjrt_batch` on the rust side).
ROUNDTRIP_BATCH = 1 << 16

# GEMM problem shape.
GEMM_DIM = 128


def takum_roundtrip_fn(n: int):
    """Return the jittable round-trip computation for width n."""

    def fn(x):
        return (takum_codec.takum_roundtrip(x, n),)

    return fn


def roundtrip_example_args():
    return (jax.ShapeDtypeStruct((ROUNDTRIP_BATCH,), jnp.float64),)


def quant_gemm_fn(n_in: int = 8, n_acc: int = 16):
    """Return the jittable quantised-GEMM computation."""

    def fn(a, b):
        return (qg.quant_gemm(a, b, n_in=n_in, n_acc=n_acc),)

    return fn


def gemm_example_args():
    spec = jax.ShapeDtypeStruct((GEMM_DIM, GEMM_DIM), jnp.float64)
    return (spec, spec)


#: All artifacts built by `make artifacts`: name -> (fn, example args).
ARTIFACTS = {
    "takum8_roundtrip": (takum_roundtrip_fn(8), roundtrip_example_args()),
    "takum16_roundtrip": (takum_roundtrip_fn(16), roundtrip_example_args()),
    "takum32_roundtrip": (takum_roundtrip_fn(32), roundtrip_example_args()),
    "quant_gemm_t8": (quant_gemm_fn(8, 16), gemm_example_args()),
}
