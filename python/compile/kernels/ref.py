"""Pure-jnp oracle for the linear-takum codec.

This mirrors, bit for bit, the rust implementation in
``rust/src/num/takum_linear.rs``: the encoder builds the exact extended
bit string ``S | D | RRR | C(r) | frac52`` in a uint64 (the takum header
is at most 12 bits, so header+52 fraction bits always fit) and rounds
once — round-to-nearest, ties-to-even on the bit string, saturating to
``[1, 2^(n-1) - 1]``. Negative values are two's complements.

The Pallas kernels in ``takum_codec.py`` are validated against these
functions by ``python/tests``; the rust side re-validates the compiled
artifacts against its native codec, closing the L1↔L3 loop.
"""

import jax.numpy as jnp
from jax import lax

# Plain-int constants: Pallas kernels must not close over device arrays,
# and Python ints fold into the trace as literals.
MASK52 = (1 << 52) - 1


def _mask(n: int) -> int:
    return (1 << n) - 1


def takum_encode(x, n: int):
    """Encode f64 array -> n-bit linear takum bit patterns (uint64)."""
    assert 2 <= n <= 56, "kernel supports n <= 56 (drop > 0 everywhere)"
    x = jnp.asarray(x, jnp.float64)
    bits = lax.bitcast_convert_type(x, jnp.uint64)
    sign = (bits >> 63).astype(jnp.bool_)
    mag = bits & 0x7FFF_FFFF_FFFF_FFFF

    is_zero = x == 0.0
    nonfinite = ~jnp.isfinite(x)

    raw_e = (mag >> 52).astype(jnp.int64)
    frac = mag & MASK52
    # Subnormal f64 inputs (raw_e == 0) are far below takum's minpos
    # (2^-1022 << 2^-255): the e = -1023 they get here saturates correctly.
    e = raw_e - 1023

    # Saturate the characteristic into the takum envelope.
    over = e > 254
    under = e < -255
    c = jnp.clip(e, -255, 254)
    frac52 = jnp.where(over, jnp.uint64(MASK52), jnp.where(under, jnp.uint64(0), frac))

    # r = floor(log2(v)) for v in [1, 256] via exact integer comparisons.
    d = c >= 0
    v = jnp.where(d, c + 1, -c)
    r = jnp.zeros_like(c)
    for k in range(1, 8):
        r = r + (v >= (1 << k)).astype(c.dtype)

    c_field = jnp.where(d, c - ((1 << r) - 1), c + (1 << (r + 1)) - 1).astype(jnp.uint64)
    big_r = jnp.where(d, r, 7 - r).astype(jnp.uint64)
    header = (d.astype(jnp.uint64) << 3) | big_r

    ru = r.astype(jnp.uint64)
    ext = (header << (ru + 52)) | (c_field << 52) | frac52
    ext_bits = ru + 57  # 5 + r + 52, including the sign bit 0
    drop = ext_bits - n  # >= 57 - n > 0 for n <= 56

    one = jnp.uint64(1)
    keep = ext >> drop
    rem = ext & ((one << drop) - 1)
    half = one << (drop - 1)
    round_up = (rem > half) | ((rem == half) & ((keep & 1) == 1))
    keep = keep + round_up.astype(jnp.uint64)
    # Saturate: never to zero, never into the NaR/negative half.
    keep = jnp.clip(keep, jnp.uint64(1), jnp.uint64(_mask(n - 1)))

    neg = (~keep + 1) & _mask(n)
    out = jnp.where(sign, neg, keep)
    out = jnp.where(is_zero, jnp.uint64(0), out)
    out = jnp.where(nonfinite, jnp.uint64(1 << (n - 1)), out)
    return out


def takum_decode(bits, n: int):
    """Decode n-bit linear takum patterns (uint64) -> f64."""
    assert 2 <= n <= 56
    bits = jnp.asarray(bits, jnp.uint64) & _mask(n)
    is_zero = bits == 0
    is_nar = bits == (1 << (n - 1))
    sign = (bits >> (n - 1)) & 1
    pos = jnp.where(sign == 1, (~bits + 1) & _mask(n), bits)

    p = max(n, 12)
    b = pos << (p - n)
    d = (b >> (p - 2)) & 1
    big_r = ((b >> (p - 5)) & 7).astype(jnp.int64)
    r = jnp.where(d == 1, big_r, 7 - big_r)
    m = (p - 5) - r
    mu = m.astype(jnp.uint64)
    one = jnp.uint64(1)
    c_field = ((b >> mu) & ((one << r.astype(jnp.uint64)) - 1)).astype(jnp.int64)
    c = jnp.where(d == 1, (1 << r) - 1 + c_field, -(1 << (r + 1)) + 1 + c_field)
    man = b & ((one << mu) - 1)

    # Assemble the f64 directly: c in [-255, 254] is always a normal f64
    # exponent; m <= p - 5 <= 52 for n <= 56 (after zero-padding p >= 12).
    val_bits = ((c + 1023).astype(jnp.uint64) << 52) | (man << (52 - mu))
    val = lax.bitcast_convert_type(val_bits, jnp.float64)
    val = jnp.where(sign == 1, -val, val)
    val = jnp.where(is_zero, 0.0, val)
    return jnp.where(is_nar, jnp.float64(jnp.nan), val)


def takum_roundtrip(x, n: int):
    """Round-trip f64 values through n-bit linear takum."""
    return takum_decode(takum_encode(x, n), n)


def quant_gemm(a, b, n_in: int = 8, n_acc: int = 16, k_chunk: int = 2):
    """Reference for the takum-quantised GEMM: quantise A and B to
    ``takum{n_in}``, multiply in f64, and re-quantise the running
    accumulator to ``takum{n_acc}`` after every ``k_chunk`` columns.
    ``k_chunk=2`` is the per-instruction `VDPPT8PT16` semantics;
    ``k_chunk=TILE`` matches the Pallas kernel's per-tile re-quantisation.
    """
    aq = takum_roundtrip(a.reshape(-1), n_in).reshape(a.shape)
    bq = takum_roundtrip(b.reshape(-1), n_in).reshape(b.shape)
    k = a.shape[1]
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.float64)
    for kk in range(0, k, k_chunk):
        part = aq[:, kk : kk + k_chunk] @ bq[kk : kk + k_chunk, :]
        acc = takum_roundtrip((acc + part).reshape(-1), n_acc).reshape(acc.shape)
    return acc
