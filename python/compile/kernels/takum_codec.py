"""L1 Pallas kernels: vectorised linear-takum codec.

The paper's compute hot-spot is per-lane format conversion (the F07
conversion matrix and the round-trip behind Figure 2). The kernel is pure
integer bit manipulation over VMEM tiles — on a real TPU this is VPU work
with lanes of int32/int64; here it is lowered with ``interpret=True`` so
the CPU PJRT client (and the rust runtime) can execute the identical HLO.

Hardware adaptation (DESIGN.md §3): the AVX 512-bit register maps to a
VMEM tile; the takum "common decoder reads at most 12 header bits"
property appears as the fixed 7-step exact `floor(log2)` ladder and
constant-width field extractions, identical for every precision n.

TPU tiling: `BLOCK` of 8×128 f64 lanes = 8 KiB per operand tile in VMEM;
encode+decode are fused in one kernel so the bits never travel back to
HBM (the round-trip artifact used by the Figure 2 sweep).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# 2-D tile; the flat roundtrip entry reshapes into (rows of 128 lanes).
BLOCK_ROWS = 8
BLOCK_COLS = 128
BLOCK = BLOCK_ROWS * BLOCK_COLS


def _roundtrip_kernel(x_ref, o_ref, *, n: int):
    x = x_ref[...]
    bits = ref.takum_encode(x, n)
    o_ref[...] = ref.takum_decode(bits, n)


def _encode_kernel(x_ref, o_ref, *, n: int):
    o_ref[...] = ref.takum_encode(x_ref[...], n)


def _decode_kernel(b_ref, o_ref, *, n: int):
    o_ref[...] = ref.takum_decode(b_ref[...], n)


def _grid_call(kernel, x, out_dtype, n: int):
    """Tile a flat array into (rows, BLOCK_COLS) blocks and run the kernel
    over a 1-D grid. Length must be a multiple of BLOCK."""
    assert x.ndim == 1 and x.shape[0] % BLOCK == 0, x.shape
    rows = x.shape[0] // BLOCK_COLS
    x2 = x.reshape(rows, BLOCK_COLS)
    out = pl.pallas_call(
        functools.partial(kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((rows, BLOCK_COLS), out_dtype),
        grid=(rows // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
        interpret=True,  # CPU-PJRT execution; Mosaic lowering is TPU-only
    )(x2)
    return out.reshape(-1)


def takum_roundtrip(x, n: int):
    """f64[N] -> f64[N], N % 1024 == 0: decode(encode(x)) in one kernel."""
    return _grid_call(_roundtrip_kernel, x, jnp.float64, n)


def takum_encode(x, n: int):
    """f64[N] -> uint64[N] bit patterns."""
    return _grid_call(_encode_kernel, x, jnp.uint64, n)


def takum_decode(bits, n: int):
    """uint64[N] -> f64[N]."""
    return _grid_call(_decode_kernel, bits, jnp.float64, n)
