"""L1 Pallas kernel: takum-quantised GEMM — the `VDPPT8PT16` pipeline as a
TPU-style tiled kernel.

Pipeline per grid step (modelled on the proposed widening dot-product
instruction): stage a takum8-quantised A-tile and B-tile into VMEM,
decode in-register, feed the MXU-shaped `jnp.dot` in f32-like precision
(f64 here, exact for the short dot products involved), and re-quantise
the accumulator tile to takum16 — encode/decode never leave the kernel.

Block choice (see DESIGN.md §8): TILE_M×TILE_K = 64×64 per operand; with
f64 staging this is 2×32 KiB decoded + 32 KiB accumulator per step,
comfortably double-bufferable in a 16 MiB VMEM. On real hardware the
decoded operands would be bf16 feeding the MXU; interpret=True keeps the
numerics identical on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = 64


def _gemm_kernel(a_ref, b_ref, o_ref, *, n_in: int, n_acc: int):
    k_step = pl.program_id(2)

    a = ref.takum_roundtrip(a_ref[...].reshape(-1), n_in).reshape(a_ref.shape)
    b = ref.takum_roundtrip(b_ref[...].reshape(-1), n_in).reshape(b_ref.shape)
    partial_sum = a @ b

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = o_ref[...] + partial_sum
    # Accumulator re-quantisation: the widening dot product writes takum
    # lanes of width n_acc.
    o_ref[...] = ref.takum_roundtrip(acc.reshape(-1), n_acc).reshape(acc.shape)


def quant_gemm(a, b, n_in: int = 8, n_acc: int = 16):
    """C = quantise(A)·quantise(B) with takum{n_acc} accumulators.

    Shapes must be multiples of TILE on every dimension.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % TILE == 0 and k % TILE == 0 and n % TILE == 0

    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_in=n_in, n_acc=n_acc),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float64),
        grid=(m // TILE, n // TILE, k // TILE),
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, j)),
        interpret=True,
    )(a, b)
